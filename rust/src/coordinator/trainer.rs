//! The SWALP training loop (Algorithm 1 / Algorithm 2 orchestration).
//!
//! One `Trainer` run = warm-up phase (low-precision SGD under the inner
//! LR schedule) followed by the averaging phase (constant SWA LR,
//! folding the low-precision weights into the host-side accumulator
//! every `cycle` steps). SGD-only runs are the same loop with averaging
//! disabled — every paper baseline is a config, not separate code.

use anyhow::Result;

use crate::data::{loader::Loader, Split};
use crate::quant::QuantFormat;
use crate::runtime::{EvalCache, EvalOut, ModelBackend, ModelState};

use super::metrics::MetricsLog;
use super::schedule::Schedule;
use super::swa::SwaAccumulator;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub total_steps: u64,
    /// Steps before averaging starts (Algorithm 2's S).
    pub warmup_steps: u64,
    /// Averaging cycle length c (in steps).
    pub cycle: u64,
    pub schedule: Schedule,
    /// Disable averaging entirely (SGD / SGD-LP baselines).
    pub enable_swa: bool,
    /// §5.1 quantized averaging: Q_SWA format for the accumulator.
    pub swa_quant: Option<QuantFormat>,
    /// Evaluate train/test every n steps (0 = only at the end).
    pub eval_every: u64,
    pub init_seed: u64,
    pub data_seed: u64,
    /// Track ‖w − w*‖² against this reference (linreg, Fig. 2 left).
    pub w_star: Option<Vec<f32>>,
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(total_steps: u64, warmup_steps: u64, cycle: u64, schedule: Schedule) -> Self {
        TrainConfig {
            total_steps,
            warmup_steps,
            cycle,
            schedule,
            enable_swa: true,
            swa_quant: None,
            eval_every: 0,
            init_seed: 1,
            data_seed: 7,
            w_star: None,
            verbose: false,
        }
    }
}

pub struct TrainOutcome {
    pub metrics: MetricsLog,
    /// Final eval of the raw (low-precision) SGD iterate.
    pub sgd_eval: EvalOut,
    /// Final eval of the SWA model (if averaging ran).
    pub swa_eval: Option<EvalOut>,
    /// Test error rate (%) helpers for classification tasks.
    pub sgd_test_err: f64,
    pub swa_test_err: Option<f64>,
    pub final_state: ModelState,
    pub swa: Option<SwaAccumulator>,
    pub steps_per_epoch: usize,
    /// Steps this run actually executed (config total minus any
    /// checkpoint-resume offset).
    pub steps: u64,
    /// Wall-clock of this run (training loop + final evals).
    pub wall_s: f64,
}

pub struct Trainer<'a> {
    pub model: &'a dyn ModelBackend,
    pub split: &'a Split,
}

impl<'a> Trainer<'a> {
    pub fn new(model: &'a dyn ModelBackend, split: &'a Split) -> Self {
        Trainer { model, split }
    }

    /// Aggregate eval over the whole test set in batch_eval chunks.
    /// Returns (mean loss, error rate in [0,1] or mean sq-err, grad_norm_sq).
    pub fn eval_set(
        &self,
        trainable: &crate::tensor::NamedTensors,
        state: &crate::tensor::NamedTensors,
        test: bool,
    ) -> Result<EvalOut> {
        self.eval_set_with(trainable, state, test, false, None)
    }

    /// Eval an SWA weight average: BatchNorm statistics are recomputed
    /// from the eval batch (Izmailov et al.'s bn_update equivalent) —
    /// running stats collected under *different* weights would otherwise
    /// wreck the averaged model's accuracy.
    ///
    /// Always uses a per-call scoped cache, never the run-long one: the
    /// averaged weights are temporaries, and a freed-then-reallocated
    /// buffer at the same address could otherwise alias a stale panel
    /// (the pointer-ABA hazard the [`EvalCache`] contract names).
    pub fn eval_swa(
        &self,
        trainable: &crate::tensor::NamedTensors,
        state: &crate::tensor::NamedTensors,
        test: bool,
    ) -> Result<EvalOut> {
        self.eval_set_with(trainable, state, test, true, None)
    }

    fn eval_set_with(
        &self,
        trainable: &crate::tensor::NamedTensors,
        state: &crate::tensor::NamedTensors,
        test: bool,
        batch_stats: bool,
        shared: Option<&EvalCache>,
    ) -> Result<EvalOut> {
        let ds = if test { &self.split.test } else { &self.split.train };
        let be = self.model.spec().batch_eval;
        let mut cursor = 0usize;
        let (mut xb, mut yb) = (Vec::new(), Vec::new());
        let mut loss = 0.0;
        let mut metric = 0.0;
        let mut gns = 0.0;
        let mut has_g = false;
        let mut batches = 0usize;
        let mut samples = 0usize;
        // One weight set against every eval batch: the loop shares an
        // EvalCache so the backend can reuse packed weight GEMM panels
        // across batches — the run-long cache when the caller passed one
        // (raw ModelState weights), else a cache scoped to this set.
        // `trainable` and `state` are borrowed for the cache's whole
        // lifetime (the stability contract); reuse is bit-identical to
        // repacking.
        let scoped;
        let cache = match shared {
            Some(c) => c,
            None => {
                scoped = EvalCache::default();
                &scoped
            }
        };
        while Loader::eval_batch(ds, be, &mut cursor, &mut xb, &mut yb) {
            let out =
                self.model.eval_batch_cached(cache, trainable, state, &xb, &yb, batch_stats)?;
            loss += out.loss;
            metric += out.metric;
            if let Some(g) = out.grad_norm_sq {
                gns += g;
                has_g = true;
            }
            batches += 1;
            samples += be;
        }
        // per-token normalization for LM metric
        let denom = if self.model.spec().task == "lm" {
            samples * self.model.spec().y_shape.iter().product::<usize>().max(1)
        } else {
            samples
        };
        Ok(EvalOut {
            loss: loss / batches.max(1) as f64,
            metric: metric / denom.max(1) as f64,
            grad_norm_sq: if has_g { Some(gns / batches.max(1) as f64) } else { None },
        })
    }

    pub fn run(&self, cfg: &TrainConfig) -> Result<TrainOutcome> {
        self.run_resumed(cfg, None)
    }

    /// Run, optionally resuming from a checkpoint (restores weights,
    /// momentum, BN state, the SWA accumulator and the step counter).
    pub fn run_resumed(
        &self,
        cfg: &TrainConfig,
        resume: Option<super::checkpoint::Checkpoint>,
    ) -> Result<TrainOutcome> {
        let timer = crate::util::Timer::start();
        let (mut ms, mut swa, start_step) = match resume {
            None => (
                self.model.init(cfg.init_seed)?,
                SwaAccumulator::new(cfg.swa_quant.clone()),
                0u64,
            ),
            Some(ck) => {
                let step = ck.step;
                // prefer the exact f64 accumulator payload; the f32 `swa`
                // section is a lossy fallback for pre-swa64 checkpoints
                let swa = match (&ck.swa64, &ck.swa) {
                    (Some((avg, m)), _) => {
                        SwaAccumulator::restore_raw(avg.clone(), *m, cfg.swa_quant.clone())
                    }
                    (None, Some((ts, m))) => {
                        SwaAccumulator::restore(ts, *m, cfg.swa_quant.clone())
                    }
                    (None, None) => SwaAccumulator::new(cfg.swa_quant.clone()),
                };
                (ck.into_model_state(), swa, step)
            }
        };
        let mut loader = Loader::new(&self.split.train, self.model.spec().batch_train, cfg.data_seed);
        let mut metrics = MetricsLog::default();
        let steps_per_epoch = loader.steps_per_epoch();
        // Resumed runs must see the same batch stream an uninterrupted run
        // would at these steps: replay the loader's shuffle state up to
        // the checkpoint (no batch materialization) so `run(ckpt at s) +
        // resume` reproduces `run` bit-for-bit.
        for _ in 0..start_step {
            loader.skip_batch();
        }

        // Run-long GEMM panel cache shared by the train steps and the
        // raw-weight eval sets: an eval over the current ModelState
        // weights leaves its packed panels for the next step's forward,
        // and each cached step bumps the cache generation after its
        // in-place weight update so stale panels can never hit. SWA
        // evals (temporary weight averages) keep per-call caches.
        let run_cache = EvalCache::default();

        for step in start_step..cfg.total_steps {
            let lr = cfg.schedule.lr_at(step) as f32;
            let (x, y) = loader.next_batch();
            // borrow juggling: copy slices out of the loader's buffers is
            // avoided — train_step reads them before the next next_batch
            let loss = {
                let (x, y): (&[f32], &[f32]) = (x, y);
                self.model.train_step_cached(&run_cache, &mut ms, x, y, lr, step)?
            };
            metrics.log(step, "train_loss", loss);

            let in_avg_phase = cfg.enable_swa && step >= cfg.warmup_steps;
            if in_avg_phase && (step - cfg.warmup_steps) % cfg.cycle == 0 {
                swa.fold(&ms.trainable)?;
            }

            if let Some(w_star) = &cfg.w_star {
                if step % 64 == 0 || step + 1 == cfg.total_steps {
                    let d = ms.trainable[0].1.data.iter().zip(w_star)
                        .map(|(&a, &b)| ((a - b) as f64).powi(2))
                        .sum::<f64>();
                    metrics.log(step, "sgd_dist_sq", d);
                    if swa.m > 0 {
                        metrics.log(step, "swa_dist_sq", swa.sq_dist_to(w_star)?);
                    }
                }
            }

            if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
                let ev =
                    self.eval_set_with(&ms.trainable, &ms.state, true, false, Some(&run_cache))?;
                metrics.log(step, "test_loss", ev.loss);
                metrics.log(step, "test_metric", ev.metric);
                if swa.m > 0 {
                    let avg = swa.average()?;
                    let evs = self.eval_swa(&avg, &ms.state, true)?;
                    metrics.log(step, "swa_test_loss", evs.loss);
                    metrics.log(step, "swa_test_metric", evs.metric);
                }
                if cfg.verbose {
                    eprintln!(
                        "step {:>7} lr {:.4} loss {:.4} test_metric {:.4}",
                        step, lr, loss, ev.metric
                    );
                }
            }
        }

        let sgd_eval =
            self.eval_set_with(&ms.trainable, &ms.state, true, false, Some(&run_cache))?;
        let (swa_eval, swa_out) = if cfg.enable_swa && swa.m > 0 {
            let avg = swa.average()?;
            (Some(self.eval_swa(&avg, &ms.state, true)?), Some(swa))
        } else {
            (None, None)
        };
        Ok(TrainOutcome {
            sgd_test_err: sgd_eval.metric * 100.0,
            swa_test_err: swa_eval.map(|e| e.metric * 100.0),
            sgd_eval,
            swa_eval,
            metrics,
            final_state: ms,
            swa: swa_out,
            steps_per_epoch,
            steps: cfg.total_steps.saturating_sub(start_step),
            wall_s: timer.secs(),
        })
    }
}
