//! Report formatting + results persistence shared by the experiment
//! harness and the benches.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Value;

/// Format a mean ± std pair like the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.2}")
}

/// Results directory (override with SWALP_RESULTS).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("SWALP_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// Persist an experiment's structured results as JSON.
pub fn save(name: &str, v: &Value) -> Result<()> {
    let path = results_dir().join(format!("{name}.json"));
    crate::util::json::write_file(&path, v)?;
    eprintln!("[results] wrote {}", path.display());
    Ok(())
}

/// Mean/std across repeated runs.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    (crate::util::mean(xs), crate::util::stddev(xs))
}

/// Log-log slope estimate between two (x, y) points — used to check
/// O(1/T) / O(δ²) scaling claims.
pub fn loglog_slope(x0: f64, y0: f64, x1: f64, y1: f64) -> f64 {
    ((y1 / y0).ln()) / ((x1 / x0).ln())
}

/// Does `path` exist under the artifacts dir? Used by benches to skip
/// gracefully when artifacts have not been built.
pub fn artifacts_ready(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formatting() {
        assert_eq!(pm(6.514, 0.141), "6.51 ± 0.14");
        assert_eq!(pct(27.2345), "27.23");
    }

    #[test]
    fn slope_of_inverse_t() {
        // y = C/T has slope -1 in log-log
        let s = loglog_slope(100.0, 1.0, 10_000.0, 0.01);
        assert!((s + 1.0).abs() < 1e-9);
    }
}
