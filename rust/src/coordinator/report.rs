//! Report formatting + results persistence shared by the experiment
//! harness and the benches.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Value;

/// Format a mean ± std pair like the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.2}")
}

/// Results directory (override with SWALP_RESULTS).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("SWALP_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

/// Persist an experiment's structured results as JSON.
pub fn save(name: &str, v: &Value) -> Result<()> {
    let path = results_dir().join(format!("{name}.json"));
    crate::util::json::write_file(&path, v)?;
    eprintln!("[results] wrote {}", path.display());
    Ok(())
}

/// Mean/std across repeated runs.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    (crate::util::mean(xs), crate::util::stddev(xs))
}

/// One-pass mean/std (Welford) for multi-seed aggregation: the batched
/// seed runner streams each replica's scalar in as it completes, no
/// intermediate vector. Matches [`mean_std`] (sample std, n−1).
#[derive(Clone, Debug, Default)]
pub struct SeedAgg {
    n: f64,
    mean: f64,
    m2: f64,
}

impl SeedAgg {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1.0;
        let d = x - self.mean;
        self.mean += d / self.n;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (0 for fewer than two samples).
    pub fn std(&self) -> f64 {
        if self.n < 2.0 {
            0.0
        } else {
            (self.m2 / (self.n - 1.0)).sqrt()
        }
    }

    pub fn count(&self) -> usize {
        self.n as usize
    }
}

/// Log-log slope estimate between two (x, y) points — used to check
/// O(1/T) / O(δ²) scaling claims.
pub fn loglog_slope(x0: f64, y0: f64, x1: f64, y1: f64) -> f64 {
    ((y1 / y0).ln()) / ((x1 / x0).ln())
}

/// Does `path` exist under the artifacts dir? Used by benches to skip
/// gracefully when artifacts have not been built.
pub fn artifacts_ready(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formatting() {
        assert_eq!(pm(6.514, 0.141), "6.51 ± 0.14");
        assert_eq!(pct(27.2345), "27.23");
    }

    #[test]
    fn slope_of_inverse_t() {
        // y = C/T has slope -1 in log-log
        let s = loglog_slope(100.0, 1.0, 10_000.0, 0.01);
        assert!((s + 1.0).abs() < 1e-9);
    }

    #[test]
    fn seed_agg_matches_two_pass_stats() {
        let xs = [6.2, 5.9, 7.1, 6.4, 6.0];
        let mut agg = SeedAgg::new();
        for &x in &xs {
            agg.push(x);
        }
        let (m, s) = mean_std(&xs);
        assert!((agg.mean() - m).abs() < 1e-12);
        assert!((agg.std() - s).abs() < 1e-12);
        assert_eq!(agg.count(), 5);
        // degenerate cases
        let mut one = SeedAgg::new();
        one.push(3.0);
        assert_eq!(one.std(), 0.0);
        assert_eq!(one.mean(), 3.0);
    }
}
