//! Structured experiment reports (`swalp-report-v1`) + the shared
//! formatting helpers.
//!
//! Every experiment the [`super::runner::Runner`] executes produces one
//! [`Report`]: per-cell mean/std aggregates (Welford [`SeedAgg`] over the
//! seed replicas), wall-clock timings and the backend id. Reports
//! serialize through [`crate::util::json`] (schema below) so CI, bench
//! tracking and cross-backend parity checks can diff them, and render to
//! the human-readable paper-style tables through one shared formatter
//! ([`Report::render`]).
//!
//! Schema (`swalp-report-v1`; arrays-of-pairs keep key order, which
//! [`crate::util::json::Value`]'s sorted objects would lose):
//!
//! ```json
//! {
//!   "schema": "swalp-report-v1",
//!   "experiment": "table1", "title": "...", "backend": "native",
//!   "mode": "quick", "seeds": 3, "wall_s": 12.5,
//!   "extras": [["q_wstar_dist", 1.2e-4]],
//!   "cells": [
//!     {"id": "cifar10/vgg/fp32",
//!      "labels": [["dataset", "cifar10"], ["model", "vgg"], ["format", "fp32"]],
//!      "quant": "fp32", "seeds": 3, "wall_s": 4.2,
//!      "metrics": [["sgd_err", {"mean": 6.51, "std": 0.14, "n": 3}]],
//!      "series": [["swalp", [[0, 1.0], [64, 0.5]]]]}
//!   ],
//!   "notes": "expected orderings ..."
//! }
//! ```
//!
//! `wall_s` fields are the only non-deterministic content; equality
//! checks go through [`Report::fingerprint`], which zeroes them.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::util::bench::Table;
use crate::util::json::Value;

pub const REPORT_SCHEMA: &str = "swalp-report-v1";

/// Format a mean ± std pair like the paper's tables.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{v:.2}")
}

/// One scalar value for the shared table formatter: plain fixed-point in
/// the human range, scientific outside it, "-" for non-finite.
pub fn num(v: f64) -> String {
    if !v.is_finite() {
        "-".into()
    } else if v != 0.0 && (v.abs() < 1e-2 || v.abs() >= 1e5) {
        format!("{v:.3e}")
    } else {
        format!("{v:.2}")
    }
}

/// Results directory (override with SWALP_RESULTS).
pub fn results_dir() -> PathBuf {
    std::env::var("SWALP_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Mean/std across repeated runs.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    (crate::util::mean(xs), crate::util::stddev(xs))
}

/// One-pass mean/std (Welford) for multi-seed aggregation: the batched
/// seed runner streams each replica's scalar in as it completes, no
/// intermediate vector. Matches [`mean_std`] (sample std, n−1).
#[derive(Clone, Debug, Default)]
pub struct SeedAgg {
    n: f64,
    mean: f64,
    m2: f64,
}

impl SeedAgg {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1.0;
        let d = x - self.mean;
        self.mean += d / self.n;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation (0 for fewer than two samples).
    pub fn std(&self) -> f64 {
        if self.n < 2.0 {
            0.0
        } else {
            (self.m2 / (self.n - 1.0)).sqrt()
        }
    }

    pub fn count(&self) -> usize {
        self.n as usize
    }

    pub fn stat(&self) -> MetricStat {
        MetricStat { mean: self.mean(), std: self.std(), n: self.count() as u64 }
    }
}

/// A seed-aggregated scalar in a report cell.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricStat {
    pub mean: f64,
    pub std: f64,
    /// How many finite seed replica values went into the aggregate.
    pub n: u64,
}

/// One grid cell (or one analytic row) of an experiment report.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Cell {
    pub id: String,
    /// Ordered table label columns, e.g. [("dataset","cifar10"), ...].
    pub labels: Vec<(String, String)>,
    /// Quantization config name of the cell's model ("" for analytic).
    pub quant: String,
    pub seeds: u64,
    /// Summed wall-clock over the cell's seed replicas.
    pub wall_s: f64,
    pub metrics: Vec<(String, MetricStat)>,
    /// Optional step curves (seed-0 replica only).
    pub series: Vec<(String, Vec<(u64, f64)>)>,
}

impl Cell {
    /// Serialize one cell; `with_timing = false` zeroes `wall_s` (the
    /// only non-deterministic field). Shared between reports and the
    /// run-ledger's `Completed` records (`crate::ledger`), so both
    /// artifacts speak one cell grammar.
    pub fn to_json(&self, with_timing: bool) -> Value {
        Value::obj(vec![
            ("id", Value::str(&self.id)),
            ("labels", pairs_str(&self.labels)),
            ("quant", Value::str(&self.quant)),
            ("seeds", Value::Num(self.seeds as f64)),
            ("wall_s", Value::Num(if with_timing { self.wall_s } else { 0.0 })),
            (
                "metrics",
                Value::Arr(
                    self.metrics
                        .iter()
                        .map(|(k, m)| {
                            Value::Arr(vec![
                                Value::str(k),
                                Value::obj(vec![
                                    ("mean", Value::Num(m.mean)),
                                    ("std", Value::Num(m.std)),
                                    ("n", Value::Num(m.n as f64)),
                                ]),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "series",
                Value::Arr(
                    self.series
                        .iter()
                        .map(|(k, pts)| {
                            Value::Arr(vec![
                                Value::str(k),
                                Value::Arr(
                                    pts.iter()
                                        .filter(|(_, v)| v.is_finite())
                                        .map(|&(s, v)| Value::arr_f64(&[s as f64, v]))
                                        .collect(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse one cell value back (inverse of [`Cell::to_json`]).
    pub fn parse(c: &Value) -> Result<Cell> {
        let mut labels = Vec::new();
        for (k, val) in parse_pairs(c.get("labels")?)? {
            labels.push((k.as_str()?.to_string(), val.as_str()?.to_string()));
        }
        let mut metrics = Vec::new();
        for (k, m) in parse_pairs(c.get("metrics")?)? {
            metrics.push((
                k.as_str()?.to_string(),
                MetricStat {
                    mean: m.get("mean")?.as_f64()?,
                    std: m.get("std")?.as_f64()?,
                    n: m.get("n")?.as_u64()?,
                },
            ));
        }
        let mut series = Vec::new();
        for (k, pts) in parse_pairs(c.get("series")?)? {
            let pts = pts
                .as_arr()?
                .iter()
                .map(|p| {
                    let p = p.as_arr()?;
                    if p.len() != 2 {
                        bail!("series point must be [step, value]");
                    }
                    Ok((p[0].as_u64()?, p[1].as_f64()?))
                })
                .collect::<Result<Vec<_>>>()?;
            series.push((k.as_str()?.to_string(), pts));
        }
        Ok(Cell {
            id: c.get("id")?.as_str()?.to_string(),
            labels,
            quant: c.get("quant")?.as_str()?.to_string(),
            seeds: c.get("seeds")?.as_u64()?,
            wall_s: c.get("wall_s")?.as_f64()?,
            metrics,
            series,
        })
    }

    /// A finished single-sample row for analytic experiments; non-finite
    /// values are dropped (JSON has no NaN/inf).
    pub fn analytic(id: &str, labels: &[(&str, &str)], metrics: &[(&str, f64)]) -> Cell {
        Cell {
            id: id.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect(),
            quant: String::new(),
            seeds: 1,
            wall_s: 0.0,
            metrics: metrics
                .iter()
                .filter(|(_, v)| v.is_finite())
                .map(|(k, v)| (k.to_string(), MetricStat { mean: *v, std: 0.0, n: 1 }))
                .collect(),
            series: vec![],
        }
    }
}

/// One experiment's structured results — the `swalp-report-v1` artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct Report {
    pub experiment: String,
    pub title: String,
    /// Execution backend id ("native", "native+xla-artifact").
    pub backend: String,
    /// Sizing tier: "full", "quick" or "smoke".
    pub mode: String,
    /// Seed replicas requested per grid cell.
    pub seeds: u64,
    /// Elapsed wall-clock of the invocation that produced this report
    /// (cells carry summed per-replica compute time instead, which can
    /// exceed this many-fold under pool execution).
    pub wall_s: f64,
    /// Report-level reference scalars (e.g. the quantization noise floor).
    pub extras: Vec<(String, f64)>,
    pub cells: Vec<Cell>,
    /// Paper-expectation commentary, printed under the table.
    pub notes: String,
}

fn pairs_str(ps: &[(String, String)]) -> Value {
    Value::Arr(
        ps.iter()
            .map(|(k, v)| Value::Arr(vec![Value::str(k), Value::str(v)]))
            .collect(),
    )
}

fn pairs_num(ps: &[(String, f64)]) -> Value {
    Value::Arr(
        ps.iter()
            .filter(|(_, v)| v.is_finite())
            .map(|(k, v)| Value::Arr(vec![Value::str(k), Value::Num(*v)]))
            .collect(),
    )
}

fn parse_pairs(v: &Value) -> Result<Vec<(&Value, &Value)>> {
    v.as_arr()?
        .iter()
        .map(|p| {
            let p = p.as_arr()?;
            if p.len() != 2 {
                bail!("expected a [key, value] pair, got {} items", p.len());
            }
            Ok((&p[0], &p[1]))
        })
        .collect()
}

impl Report {
    /// Serialize; `with_timing = false` zeroes the wall-clock fields,
    /// which is what makes reports comparable across thread counts.
    pub fn to_json(&self, with_timing: bool) -> Value {
        let wall = |w: f64| if with_timing { w } else { 0.0 };
        let cells = self.cells.iter().map(|c| c.to_json(with_timing)).collect();
        Value::obj(vec![
            ("schema", Value::str(REPORT_SCHEMA)),
            ("experiment", Value::str(&self.experiment)),
            ("title", Value::str(&self.title)),
            ("backend", Value::str(&self.backend)),
            ("mode", Value::str(&self.mode)),
            ("seeds", Value::Num(self.seeds as f64)),
            ("wall_s", Value::Num(wall(self.wall_s))),
            ("extras", pairs_num(&self.extras)),
            ("cells", Value::Arr(cells)),
            ("notes", Value::str(&self.notes)),
        ])
    }

    /// Parse a `swalp-report-v1` value back into a [`Report`].
    pub fn parse(v: &Value) -> Result<Report> {
        let schema = v.get("schema")?.as_str()?;
        if schema != REPORT_SCHEMA {
            bail!("unsupported report schema {schema:?} (want {REPORT_SCHEMA})");
        }
        let mut cells = Vec::new();
        for c in v.get("cells")?.as_arr()? {
            cells.push(Cell::parse(c)?);
        }
        let mut extras = Vec::new();
        for (k, val) in parse_pairs(v.get("extras")?)? {
            extras.push((k.as_str()?.to_string(), val.as_f64()?));
        }
        Ok(Report {
            experiment: v.get("experiment")?.as_str()?.to_string(),
            title: v.get("title")?.as_str()?.to_string(),
            backend: v.get("backend")?.as_str()?.to_string(),
            mode: v.get("mode")?.as_str()?.to_string(),
            seeds: v.get("seeds")?.as_u64()?,
            wall_s: v.get("wall_s")?.as_f64()?,
            extras,
            cells,
            notes: v.get("notes")?.as_str()?.to_string(),
        })
    }

    /// Canonical serialization with the timing fields zeroed — equal
    /// across thread counts for a deterministic runner.
    pub fn fingerprint(&self) -> String {
        self.to_json(false).to_string()
    }

    /// The one shared human-readable formatter: a paper-style table whose
    /// columns are the union of label keys and metric names across cells
    /// (first-appearance order), then the reference extras and notes.
    pub fn render(&self) {
        println!("== {} ==", self.title);
        let mut label_keys: Vec<&str> = Vec::new();
        let mut metric_keys: Vec<&str> = Vec::new();
        for c in &self.cells {
            for (k, _) in &c.labels {
                if !label_keys.contains(&k.as_str()) {
                    label_keys.push(k);
                }
            }
            for (k, _) in &c.metrics {
                if !metric_keys.contains(&k.as_str()) {
                    metric_keys.push(k);
                }
            }
        }
        let headers: Vec<&str> = label_keys.iter().chain(metric_keys.iter()).copied().collect();
        let mut table = Table::new(&headers);
        for c in &self.cells {
            let mut row: Vec<String> = label_keys
                .iter()
                .map(|k| {
                    c.labels
                        .iter()
                        .find(|(lk, _)| lk == k)
                        .map(|(_, v)| v.clone())
                        .unwrap_or_else(|| "-".into())
                })
                .collect();
            for k in &metric_keys {
                row.push(match c.metrics.iter().find(|(mk, _)| mk == k) {
                    None => "-".into(),
                    Some((_, m)) if m.n >= 2 => pm(m.mean, m.std),
                    Some((_, m)) => num(m.mean),
                });
            }
            table.row(row);
        }
        table.print();
        for (k, v) in &self.extras {
            println!("reference: {k} = {}", num(*v));
        }
        if !self.notes.is_empty() {
            println!("{}", self.notes);
        }
        println!(
            "[{} | {} mode | seeds={} | backend={} | {:.1}s]",
            self.experiment, self.mode, self.seeds, self.backend, self.wall_s
        );
    }

    /// Persist under `dir/<experiment>.json`.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("{}.json", self.experiment.replace('-', "_")));
        crate::util::json::write_file(&path, &self.to_json(true))?;
        Ok(path)
    }
}

/// Log-log slope estimate between two (x, y) points — used to check
/// O(1/T) / O(δ²) scaling claims.
pub fn loglog_slope(x0: f64, y0: f64, x1: f64, y1: f64) -> f64 {
    ((y1 / y0).ln()) / ((x1 / x0).ln())
}

/// Does `path` exist under the artifacts dir? Used by benches to skip
/// gracefully when artifacts have not been built.
pub fn artifacts_ready(dir: &Path) -> bool {
    dir.join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formatting() {
        assert_eq!(pm(6.514, 0.141), "6.51 ± 0.14");
        assert_eq!(pct(27.2345), "27.23");
        assert_eq!(num(6.514), "6.51");
        assert_eq!(num(1.5e-4), "1.500e-4");
        assert_eq!(num(f64::NAN), "-");
        assert_eq!(num(0.0), "0.00");
    }

    #[test]
    fn slope_of_inverse_t() {
        // y = C/T has slope -1 in log-log
        let s = loglog_slope(100.0, 1.0, 10_000.0, 0.01);
        assert!((s + 1.0).abs() < 1e-9);
    }

    #[test]
    fn seed_agg_matches_two_pass_stats() {
        let xs = [6.2, 5.9, 7.1, 6.4, 6.0];
        let mut agg = SeedAgg::new();
        for &x in &xs {
            agg.push(x);
        }
        let (m, s) = mean_std(&xs);
        assert!((agg.mean() - m).abs() < 1e-12);
        assert!((agg.std() - s).abs() < 1e-12);
        assert_eq!(agg.count(), 5);
        let st = agg.stat();
        assert_eq!(st.n, 5);
        assert_eq!(st.mean, agg.mean());
        // degenerate cases
        let mut one = SeedAgg::new();
        one.push(3.0);
        assert_eq!(one.std(), 0.0);
        assert_eq!(one.mean(), 3.0);
    }
}
