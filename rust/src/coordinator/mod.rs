//! L3 coordinator: the paper's training orchestration (Algorithms 1 & 2).
//!
//! The split of responsibilities mirrors the paper's proposed hardware
//! story (§3.3): the low-precision SGD inner step runs on the
//! "accelerator" (the compiled XLA artifact), while the weight average —
//! touched once per cycle, stored in high precision — lives on the
//! "host" (this module, plain rust f64). The §5.1 variant quantizes the
//! averaging workload too ([`swa::SwaAccumulator`] with a Q_SWA format).

pub mod checkpoint;
pub mod experiment;
pub mod metrics;
pub mod registry;
pub mod report;
pub mod runner;
pub mod schedule;
pub mod swa;
pub mod trainer;

pub use experiment::{Ctx, CtxConfig};
pub use report::Report;
pub use runner::Runner;
pub use schedule::Schedule;
pub use swa::SwaAccumulator;
pub use trainer::{TrainConfig, TrainOutcome, Trainer};
