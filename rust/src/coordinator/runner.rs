//! The experiment runner: flattens registry specs into one
//! `cells × seed replicas` work list and shards it across the rayon pool.
//!
//! One [`Runner::run_many`] call covers everything from a single
//! experiment to the full `--all` sweep: every grid cell of every
//! requested spec becomes `seeds` work items in a single flat list, so a
//! 12-cell table grid saturates the pool even with one seed replica per
//! cell (the PR-2 `run_seeds` path could only parallelize within one
//! model). Execution is deterministic by construction — a training run
//! is a pure function of its `TrainConfig`, and per-cell seeding derives
//! from the cell's `RunSpec`, not from scheduling order — so reports are
//! bit-identical (modulo wall-clock fields) at any thread count;
//! `ctx.threads() == Some(1)` runs the same list serially on the calling
//! thread as the reference.
//!
//! Backends are loaded and datasets built on the calling thread up front
//! (artifact compilation is not re-entrant); workers only train and
//! evaluate.
//!
//! ```no_run
//! use swalp::coordinator::{registry, CtxConfig, Runner};
//!
//! // reproduce one registered experiment in quick mode and read the
//! // structured swalp-report-v1 result (see docs/PERF.md for the schema)
//! let ctx = CtxConfig::new().quick(true).build().unwrap();
//! let spec = registry::find("fig2-linreg").expect("registered id");
//! let report = Runner::new(&ctx).run(spec).unwrap();
//! println!("{} cells from backend {}", report.cells.len(), report.backend);
//! ```

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::coordinator::{TrainConfig, Trainer};
use crate::data::{self, Split};
use crate::ledger::{record::now_ts, CellKey, Ledger, Record};
use crate::runtime::ModelBackend;
use crate::util::Timer;

use super::experiment::{Ctx, CtxConfig};
use super::registry::{
    self, CyclePolicy, DataSpec, EvalKind, ExpKind, ExperimentSpec, RunSpec, Sizing,
};
use super::report::{Cell, MetricStat, Report, SeedAgg};

/// Executes registry experiments against a [`Ctx`].
pub struct Runner<'a> {
    ctx: &'a Ctx,
}

/// Training data resolved before execution. Cells with the same
/// [`DataSpec`] (and dataset, for model-derived splits) share one entry
/// — a table grid builds its split once, not once per format column.
struct CellData {
    split: Split,
    /// Empirical optimum for ‖w−w*‖² tracking (linreg cells).
    w_star: Option<Vec<f32>>,
}

/// One (spec, cell, seed) work item.
struct WorkItem<'a> {
    spec_i: usize,
    cell_i: usize,
    seed: u64,
    model: Box<dyn ModelBackend>,
    rs: &'a RunSpec,
    data: &'a CellData,
}

/// What a single replica contributes to its cell.
struct SeedOut {
    metrics: Vec<(String, f64)>,
    series: Vec<(String, Vec<(u64, f64)>)>,
    wall_s: f64,
}

impl<'a> Runner<'a> {
    pub fn new(ctx: &'a Ctx) -> Runner<'a> {
        Runner { ctx }
    }

    /// Run one experiment.
    pub fn run(&self, spec: &ExperimentSpec) -> Result<Report> {
        Ok(self.run_many(&[spec])?.pop().expect("one spec in, one report out"))
    }

    /// Run several experiments over ONE flattened work list: all grid
    /// cells × seed replicas execute concurrently across the pool, then
    /// results aggregate back into one report per spec (input order).
    pub fn run_many(&self, specs: &[&ExperimentSpec]) -> Result<Vec<Report>> {
        let ctx = self.ctx;
        let total_timer = Timer::start();
        // resolve grids + per-cell quant/data on the calling thread;
        // identical DataSpecs share one built split across cells/specs
        let mut grids: Vec<Vec<RunSpec>> = Vec::with_capacity(specs.len());
        let mut quants: Vec<Vec<String>> = Vec::with_capacity(specs.len());
        let mut data_of: Vec<Vec<usize>> = Vec::with_capacity(specs.len());
        let mut pool_keys: Vec<String> = Vec::new();
        let mut pool: Vec<CellData> = Vec::new();
        for spec in specs {
            let cells = match &spec.kind {
                ExpKind::Grid { cells, .. } => cells(ctx),
                ExpKind::Analytic(_) => vec![],
            };
            let mut cell_quants = Vec::with_capacity(cells.len());
            let mut cell_data = Vec::with_capacity(cells.len());
            for rs in &cells {
                let model = ctx.load(&rs.model)?;
                cell_quants.push(model.spec().quant.name.clone());
                let key = match rs.data {
                    DataSpec::Model { seed, scale } => format!(
                        "model/{}/{seed}/{:x}",
                        model.spec().dataset,
                        scale.to_bits()
                    ),
                    DataSpec::LinregWstar { d, n, seed } => format!("linreg/{d}/{n}/{seed}"),
                };
                let idx = match pool_keys.iter().position(|k| *k == key) {
                    Some(i) => i,
                    None => {
                        pool.push(build_data(rs, &model.spec().dataset)?);
                        pool_keys.push(key);
                        pool.len() - 1
                    }
                };
                cell_data.push(idx);
            }
            grids.push(cells);
            quants.push(cell_quants);
            data_of.push(cell_data);
        }

        // flatten into the work list (backends loaded up front)
        let mut items: Vec<WorkItem> = Vec::new();
        for (spec_i, cells) in grids.iter().enumerate() {
            for (cell_i, rs) in cells.iter().enumerate() {
                for seed in 0..rs.seeds.max(1) {
                    items.push(WorkItem {
                        spec_i,
                        cell_i,
                        seed,
                        model: ctx.load(&rs.model)?,
                        rs,
                        data: &pool[data_of[spec_i][cell_i]],
                    });
                }
            }
        }

        // resumable execution: with `--ledger`, every item has a stable
        // CellKey; Completed records prefill their slot bit-identically
        // (f64 metric/series values survive the JSON round-trip exactly),
        // everything else is Submitted before any work starts
        let mut slots: Vec<Option<Result<SeedOut>>> = Vec::new();
        slots.resize_with(items.len(), || None);
        let ledger: Option<Mutex<Ledger>> = match ctx.ledger_dir() {
            Some(dir) => Some(Mutex::new(Ledger::open(dir)?)),
            None => None,
        };
        let backend = ctx.backend_id();
        let keys: Vec<Option<CellKey>> = items
            .iter()
            .map(|it| {
                ledger
                    .as_ref()
                    .map(|_| CellKey::new(specs[it.spec_i].id, it.rs, it.seed, &backend))
            })
            .collect();
        if let Some(led) = &ledger {
            let mut l = led.lock().unwrap();
            for ((item, key), slot) in items.iter().zip(&keys).zip(slots.iter_mut()) {
                let key = key.as_ref().expect("keys exist when ledger active");
                if let Some(cell) = l.completed(key) {
                    *slot = Some(Ok(seed_out_from_cell(cell)));
                } else if !l.knows(key) {
                    l.append(&Record::Submitted {
                        key: key.clone(),
                        experiment: specs[item.spec_i].id.to_string(),
                        cell: item.rs.id.clone(),
                        seed: item.seed,
                    })?;
                }
            }
        }

        // execute: rayon pool by default, serial when threads = 1; each
        // ledgered item appends Started, then Completed (with its full
        // Cell payload) or Failed — fsync'd before the result is used
        let quants = &quants;
        let exec = |item: &WorkItem, key: Option<&CellKey>| -> Result<SeedOut> {
            let Some(led) = &ledger else {
                return run_item(item);
            };
            let key = key.expect("key computed when ledger active");
            let attempt = {
                let mut l = led.lock().unwrap();
                let attempt = l.next_attempt(key);
                l.append(&Record::Started { key: key.clone(), attempt, ts: now_ts() })?;
                attempt
            };
            match run_item(item) {
                Ok(out) => {
                    let cell = item_cell(item, &out, &quants[item.spec_i][item.cell_i]);
                    led.lock()
                        .unwrap()
                        .append(&Record::Completed { key: key.clone(), cell, ts: now_ts() })?;
                    Ok(out)
                }
                Err(e) => {
                    led.lock().unwrap().append(&Record::Failed {
                        key: key.clone(),
                        attempt,
                        error: format!("{e:#}"),
                        ts: now_ts(),
                    })?;
                    Err(e)
                }
            }
        };
        let exec = &exec;
        if ctx.threads() == Some(1) {
            for ((item, key), slot) in items.iter().zip(&keys).zip(slots.iter_mut()) {
                if slot.is_none() {
                    *slot = Some(exec(item, key.as_ref()));
                }
            }
        } else {
            rayon::scope(|s| {
                for ((item, key), slot) in items.iter().zip(&keys).zip(slots.iter_mut()) {
                    if slot.is_none() {
                        s.spawn(move |_| {
                            *slot = Some(exec(item, key.as_ref()));
                        });
                    }
                }
            });
        }
        let mut outs: Vec<SeedOut> = Vec::with_capacity(slots.len());
        for (slot, item) in slots.into_iter().zip(&items) {
            outs.push(
                slot.expect("work item did not run")
                    .map_err(|e| e.context(format!("cell {} seed {}", item.rs.id, item.seed)))?,
            );
        }

        // aggregate per (spec, cell), then assemble one report per spec
        let mut reports = Vec::with_capacity(specs.len());
        for (spec_i, spec) in specs.iter().enumerate() {
            let mut cells_out: Vec<Cell> = Vec::new();
            for (cell_i, rs) in grids[spec_i].iter().enumerate() {
                let mut aggs: Vec<(String, SeedAgg)> = Vec::new();
                let mut series = Vec::new();
                let mut wall = 0.0;
                for (item, out) in items.iter().zip(&outs) {
                    if item.spec_i != spec_i || item.cell_i != cell_i {
                        continue;
                    }
                    wall += out.wall_s;
                    if item.seed == 0 {
                        series = out.series.clone();
                    }
                    for (name, v) in &out.metrics {
                        if !v.is_finite() {
                            continue;
                        }
                        match aggs.iter_mut().find(|(n, _)| n == name) {
                            Some((_, agg)) => agg.push(*v),
                            None => {
                                let mut agg = SeedAgg::new();
                                agg.push(*v);
                                aggs.push((name.clone(), agg));
                            }
                        }
                    }
                }
                cells_out.push(Cell {
                    id: rs.id.clone(),
                    labels: rs.labels.clone(),
                    quant: quants[spec_i][cell_i].clone(),
                    seeds: rs.seeds.max(1),
                    wall_s: wall,
                    metrics: aggs.into_iter().map(|(n, a)| (n, a.stat())).collect(),
                    series,
                });
            }
            let mut extras = Vec::new();
            match &spec.kind {
                ExpKind::Grid { extras: Some(f), .. } => extras = f(ctx)?,
                ExpKind::Grid { .. } => {}
                ExpKind::Analytic(f) => cells_out = f(ctx)?,
            }
            reports.push(Report {
                experiment: spec.id.to_string(),
                title: spec.title.to_string(),
                backend: ctx.backend_id(),
                mode: ctx.mode().to_string(),
                seeds: ctx.seeds(),
                // elapsed wall-clock of this invocation so far — NOT the
                // summed replica time (cells carry those); under pool
                // execution the sum can exceed elapsed many-fold
                wall_s: total_timer.secs(),
                extras,
                cells: cells_out,
                notes: spec.notes.to_string(),
            });
        }
        Ok(reports)
    }
}

/// The ledger payload of one finished replica: a one-seed [`Cell`].
/// Non-finite metrics are dropped here (JSON cannot carry them), which
/// matches the aggregation loop skipping them — so a resumed aggregate
/// equals a live one.
fn item_cell(item: &WorkItem, out: &SeedOut, quant: &str) -> Cell {
    Cell {
        id: item.rs.id.clone(),
        labels: item.rs.labels.clone(),
        quant: quant.to_string(),
        seeds: 1,
        wall_s: out.wall_s,
        metrics: out
            .metrics
            .iter()
            .filter(|(_, v)| v.is_finite())
            .map(|(k, v)| (k.clone(), MetricStat { mean: *v, std: 0.0, n: 1 }))
            .collect(),
        series: out.series.clone(),
    }
}

/// Reconstruct a replica contribution from its stored ledger payload
/// (inverse of [`item_cell`]; single-seed stats carry mean = value).
fn seed_out_from_cell(cell: &Cell) -> SeedOut {
    SeedOut {
        metrics: cell.metrics.iter().map(|(k, m)| (k.clone(), m.mean)).collect(),
        series: cell.series.clone(),
        wall_s: cell.wall_s,
    }
}

/// Build one shared training-data entry for a cell.
fn build_data(rs: &RunSpec, dataset: &str) -> Result<CellData> {
    Ok(match rs.data {
        DataSpec::Model { seed, scale } => {
            CellData { split: data::build(dataset, seed, scale)?, w_star: None }
        }
        DataSpec::LinregWstar { d, n, seed } => {
            let problem = data::synth::linreg_problem(d, n, seed);
            CellData { split: problem.split, w_star: Some(problem.w_star) }
        }
    })
}

/// Train one cell replica and compute its report metrics.
fn run_item(item: &WorkItem) -> Result<SeedOut> {
    let t = Timer::start();
    let rs = item.rs;
    let model = &*item.model;
    let split = &item.data.split;
    let spe = (split.train.n / model.spec().batch_train).max(1) as u64;
    let (steps, warmup) = match rs.sizing {
        Sizing::Steps { steps, warmup } => (steps, warmup),
        Sizing::Epochs { warmup, avg } => (warmup * spe + avg * spe, warmup * spe),
    };
    // an averaging run needs at least one post-warm-up step to fold
    let steps = if rs.enable_swa { steps.max(warmup + 1) } else { steps };
    let cycle = match rs.cycle {
        CyclePolicy::Steps(c) => c.max(1),
        CyclePolicy::PerEpoch(f) => (spe / f.max(1)).max(1),
    };
    let mut cfg = TrainConfig::new(steps, warmup, cycle, rs.sched.resolve(warmup));
    cfg.enable_swa = rs.enable_swa;
    cfg.init_seed = rs.init_seed + item.seed;
    cfg.data_seed = rs.data_seed + item.seed;
    if matches!(rs.eval, EvalKind::DistSq) {
        cfg.w_star = item.data.w_star.clone();
    }
    if matches!(rs.eval, EvalKind::SwaTrajectory) {
        cfg.eval_every = spe;
    }
    let trainer = Trainer::new(model, split);
    let out = trainer.run(&cfg)?;

    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut series: Vec<(String, Vec<(u64, f64)>)> = Vec::new();
    let mut push = |name: &str, v: f64| metrics.push((name.to_string(), v));
    match rs.eval {
        EvalKind::TestErr => {
            push("sgd_err", out.sgd_test_err);
            if let Some(swa) = out.swa_test_err {
                push("swalp_err", swa);
                push("gain", out.sgd_test_err - swa);
            }
        }
        EvalKind::DistSq => {
            let key = if rs.enable_swa { "swa_dist_sq" } else { "sgd_dist_sq" };
            let curve = out.metrics.series(key);
            let final_d = curve.last().map(|&(_, v)| v).unwrap_or(f64::NAN);
            push("final_dist_sq", final_d);
            if let Some(w_star) = &item.data.w_star {
                push("vs_qnoise", final_d / registry::q_wstar_dist(w_star));
            }
            // O(1/T) check on the averaged curve (Theorem 1 predicts -1)
            if rs.enable_swa && curve.len() >= 4 {
                let a = curve[curve.len() / 2];
                let b = curve[curve.len() - 1];
                push(
                    "tail_slope",
                    super::report::loglog_slope(a.0 as f64, a.1, b.0 as f64, b.1),
                );
            }
            series.push((key.to_string(), curve));
        }
        EvalKind::GradNorm => {
            // gradient norm of the FP TRAINING objective (the quantity
            // Theorem 2 bounds) at the SGD iterate...
            let g_iter = trainer
                .eval_set(&out.final_state.trainable, &out.final_state.state, false)?
                .grad_norm_sq
                .unwrap_or(f64::NAN);
            push("grad_iter", g_iter);
            // ...and at the averaged model
            if let Some(acc) = &out.swa {
                let avg = acc.average()?;
                let g_avg = trainer
                    .eval_swa(&avg, &out.final_state.state, false)?
                    .grad_norm_sq
                    .unwrap_or(f64::NAN);
                push("grad_avg", g_avg);
            }
        }
        EvalKind::TrainTestErr => {
            let sgd_train = trainer
                .eval_set(&out.final_state.trainable, &out.final_state.state, false)?
                .metric
                * 100.0;
            push("sgd_train", sgd_train);
            push("sgd_test", out.sgd_test_err);
            if let Some(acc) = &out.swa {
                let avg = acc.average()?;
                let swa_train =
                    trainer.eval_swa(&avg, &out.final_state.state, false)?.metric * 100.0;
                push("swa_train", swa_train);
                if let Some(swa_test) = out.swa_test_err {
                    push("swa_test", swa_test);
                }
            }
        }
        EvalKind::Perplexity => {
            // exp(mean per-token test CE): the graph's SoftmaxCe head
            // normalizes token tasks per row, so `loss` is already the
            // per-token mean
            let sgd_ppl = out.sgd_eval.loss.exp();
            push("sgd_ppl", sgd_ppl);
            if let Some(e) = &out.swa_eval {
                let swalp_ppl = e.loss.exp();
                push("swalp_ppl", swalp_ppl);
                push("gain", sgd_ppl - swalp_ppl);
            }
        }
        EvalKind::SwaTrajectory => {
            let curve = out.metrics.series("swa_test_metric");
            let after1 = curve
                .iter()
                .find(|(s, _)| *s >= warmup + spe - 1)
                .map(|&(_, v)| v * 100.0)
                .unwrap_or(f64::NAN);
            push("after_1_epoch", after1);
            if let Some(final_err) = out.swa_test_err {
                push("final_err", final_err);
            }
        }
    }
    let wall_s = t.secs();
    eprintln!("[{}] seed {} done in {:.1}s", rs.id, item.seed, wall_s);
    Ok(SeedOut { metrics, series, wall_s })
}

/// Shared entry point for the paper-figure benches: quick mode by
/// default, `--full`/`SWALP_FULL=1` for the full-scale version, `--seeds
/// N` replicas, `--threads 1` for the serial reference. The experiment's
/// models must be loadable — an unavailable backend is a hard error, not
/// a skip: these benches executing real training steps is an acceptance
/// gate for the native engine.
pub fn bench_main(exp: &str) {
    let args = crate::util::cli::Args::from_env();
    let full = args.flag("full") || std::env::var("SWALP_FULL").is_ok();
    if let Err(e) = bench_run(exp, full, &args) {
        eprintln!("{exp} failed: {e:#}");
        std::process::exit(1);
    }
}

fn bench_run(exp: &str, full: bool, args: &crate::util::cli::Args) -> Result<()> {
    let mut cfg = CtxConfig::new().quick(!full).seeds(args.u64_or("seeds", 1)?);
    if args.flag("smoke") {
        cfg = cfg.smoke(true);
    }
    if let Some(t) = args.opt("threads") {
        cfg = cfg.threads(t.parse()?);
    }
    if let Some(dir) = args.opt("ledger") {
        cfg = cfg.ledger(dir);
    }
    let ctx = cfg.build()?;
    let Some(spec) = registry::find(exp) else {
        bail!("unknown experiment {exp:?}; registered: {}", registry::ids().join(" "));
    };
    if let ExpKind::Grid { cells, .. } = &spec.kind {
        for rs in cells(&ctx) {
            if !ctx.can_load(&rs.model) {
                bail!(
                    "model {:?} unavailable on every backend.\nregistered native models:\n  {}",
                    rs.model,
                    crate::native::model_names().join("\n  ")
                );
            }
        }
    }
    let report = Runner::new(&ctx).run(spec)?;
    report.render();
    let path = report.save(&ctx.results_dir())?;
    eprintln!("[results] wrote {}", path.display());
    Ok(())
}
