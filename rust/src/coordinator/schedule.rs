//! Learning-rate schedules (paper Appendix I).

/// All schedules are pure functions of the global step.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Constant α (SWALP's averaging phase uses a constant SWA LR).
    Constant(f64),
    /// The paper's SGD budget schedule: α₁ for the first half of the
    /// budget, linear decay to 0.01·α₁ at 0.9 budgets, then constant.
    PaperSgd { alpha1: f64, budget: u64 },
    /// ImageNet-style step decay: α₁ · factor^(step / every).
    StepDecay { alpha1: f64, factor: f64, every: u64 },
    /// Warm-up with `inner` for `warmup` steps, then constant `swa_lr` —
    /// the SWALP composite schedule (App. I: decay low before averaging
    /// starts, then hold constant).
    Swalp { inner: Box<Schedule>, warmup: u64, swa_lr: f64 },
}

impl Schedule {
    pub fn lr_at(&self, step: u64) -> f64 {
        match self {
            Schedule::Constant(a) => *a,
            Schedule::PaperSgd { alpha1, budget } => {
                let t = step as f64 / (*budget).max(1) as f64;
                if t < 0.5 {
                    *alpha1
                } else if t < 0.9 {
                    let frac = (t - 0.5) / 0.4;
                    alpha1 * (1.0 - frac * 0.99)
                } else {
                    alpha1 * 0.01
                }
            }
            Schedule::StepDecay { alpha1, factor, every } => {
                let every = (*every).max(1);
                alpha1 * factor.powi((step / every) as i32)
            }
            Schedule::Swalp { inner, warmup, swa_lr } => {
                if step < *warmup {
                    inner.lr_at(step)
                } else {
                    *swa_lr
                }
            }
        }
    }

    /// The paper's SWALP deep-learning schedule: SGD budget decay during
    /// warm-up, then a constant averaging LR.
    pub fn swalp_paper(alpha1: f64, warmup: u64, swa_lr: f64) -> Schedule {
        Schedule::Swalp {
            inner: Box::new(Schedule::PaperSgd { alpha1, budget: warmup }),
            warmup,
            swa_lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sgd_shape() {
        let s = Schedule::PaperSgd { alpha1: 0.1, budget: 1000 };
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(499), 0.1);
        // at 0.9 budget the LR has decayed to 0.01·α₁
        assert!((s.lr_at(900) - 0.001).abs() < 1e-4);
        assert!((s.lr_at(999) - 0.001).abs() < 1e-9);
        // monotone non-increasing
        let mut prev = f64::MAX;
        for t in (0..1000).step_by(50) {
            let lr = s.lr_at(t);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn swalp_schedule_holds_constant_after_warmup() {
        let s = Schedule::swalp_paper(0.1, 1000, 0.01);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(1000), 0.01);
        assert_eq!(s.lr_at(50_000), 0.01);
    }

    #[test]
    fn step_decay() {
        let s = Schedule::StepDecay { alpha1: 0.1, factor: 0.1, every: 100 };
        assert_eq!(s.lr_at(0), 0.1);
        assert!((s.lr_at(100) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(250) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn paper_sgd_piecewise_values_exact() {
        // the three pieces of App. I's budget schedule, checked pointwise:
        // α₁ for t<0.5, linear α₁·(1 − 0.99·(t−0.5)/0.4) on [0.5, 0.9),
        // 0.01·α₁ beyond
        let s = Schedule::PaperSgd { alpha1: 0.2, budget: 1000 };
        assert_eq!(s.lr_at(0), 0.2);
        assert_eq!(s.lr_at(250), 0.2);
        assert_eq!(s.lr_at(499), 0.2);
        // t = 0.6 -> frac 0.25 -> 0.2·(1 − 0.2475)
        assert!((s.lr_at(600) - 0.2 * (1.0 - 0.25 * 0.99)).abs() < 1e-12);
        // t = 0.7 -> frac 0.5 -> 0.2·0.505 = 0.101
        assert!((s.lr_at(700) - 0.101).abs() < 1e-12);
        // t = 0.8 -> frac 0.75
        assert!((s.lr_at(800) - 0.2 * (1.0 - 0.75 * 0.99)).abs() < 1e-12);
        // final plateau at 0.01·α₁
        assert!((s.lr_at(900) - 0.002).abs() < 1e-4);
        assert!((s.lr_at(950) - 0.002).abs() < 1e-12);
        assert!((s.lr_at(10_000) - 0.002).abs() < 1e-12);
    }

    #[test]
    fn swalp_warmup_boundary_is_exact() {
        let s = Schedule::Swalp {
            inner: Box::new(Schedule::StepDecay { alpha1: 0.4, factor: 0.5, every: 10 }),
            warmup: 25,
            swa_lr: 0.07,
        };
        // inner decay drives steps 0..24
        assert_eq!(s.lr_at(0), 0.4);
        assert_eq!(s.lr_at(10), 0.2);
        assert_eq!(s.lr_at(24), 0.1);
        // from the warm-up boundary on, constant SWA LR
        assert_eq!(s.lr_at(25), 0.07);
        assert_eq!(s.lr_at(26), 0.07);
        assert_eq!(s.lr_at(1_000_000), 0.07);
    }

    #[test]
    fn constant_is_constant_and_zero_budget_is_safe() {
        assert_eq!(Schedule::Constant(0.3).lr_at(0), 0.3);
        assert_eq!(Schedule::Constant(0.3).lr_at(u64::MAX), 0.3);
        // budget 0 must not divide by zero
        let s = Schedule::PaperSgd { alpha1: 0.1, budget: 0 };
        assert!(s.lr_at(0).is_finite());
        let s = Schedule::StepDecay { alpha1: 0.1, factor: 0.5, every: 0 };
        assert!(s.lr_at(5).is_finite());
    }
}
