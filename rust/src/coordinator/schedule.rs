//! Learning-rate schedules (paper Appendix I).

/// All schedules are pure functions of the global step.
#[derive(Clone, Debug)]
pub enum Schedule {
    /// Constant α (SWALP's averaging phase uses a constant SWA LR).
    Constant(f64),
    /// The paper's SGD budget schedule: α₁ for the first half of the
    /// budget, linear decay to 0.01·α₁ at 0.9 budgets, then constant.
    PaperSgd { alpha1: f64, budget: u64 },
    /// ImageNet-style step decay: α₁ · factor^(step / every).
    StepDecay { alpha1: f64, factor: f64, every: u64 },
    /// Warm-up with `inner` for `warmup` steps, then constant `swa_lr` —
    /// the SWALP composite schedule (App. I: decay low before averaging
    /// starts, then hold constant).
    Swalp { inner: Box<Schedule>, warmup: u64, swa_lr: f64 },
}

impl Schedule {
    pub fn lr_at(&self, step: u64) -> f64 {
        match self {
            Schedule::Constant(a) => *a,
            Schedule::PaperSgd { alpha1, budget } => {
                let t = step as f64 / (*budget).max(1) as f64;
                if t < 0.5 {
                    *alpha1
                } else if t < 0.9 {
                    let frac = (t - 0.5) / 0.4;
                    alpha1 * (1.0 - frac * 0.99)
                } else {
                    alpha1 * 0.01
                }
            }
            Schedule::StepDecay { alpha1, factor, every } => {
                let every = (*every).max(1);
                alpha1 * factor.powi((step / every) as i32)
            }
            Schedule::Swalp { inner, warmup, swa_lr } => {
                if step < *warmup {
                    inner.lr_at(step)
                } else {
                    *swa_lr
                }
            }
        }
    }

    /// The paper's SWALP deep-learning schedule: SGD budget decay during
    /// warm-up, then a constant averaging LR.
    pub fn swalp_paper(alpha1: f64, warmup: u64, swa_lr: f64) -> Schedule {
        Schedule::Swalp {
            inner: Box::new(Schedule::PaperSgd { alpha1, budget: warmup }),
            warmup,
            swa_lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sgd_shape() {
        let s = Schedule::PaperSgd { alpha1: 0.1, budget: 1000 };
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(499), 0.1);
        // at 0.9 budget the LR has decayed to 0.01·α₁
        assert!((s.lr_at(900) - 0.001).abs() < 1e-4);
        assert!((s.lr_at(999) - 0.001).abs() < 1e-9);
        // monotone non-increasing
        let mut prev = f64::MAX;
        for t in (0..1000).step_by(50) {
            let lr = s.lr_at(t);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
    }

    #[test]
    fn swalp_schedule_holds_constant_after_warmup() {
        let s = Schedule::swalp_paper(0.1, 1000, 0.01);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(1000), 0.01);
        assert_eq!(s.lr_at(50_000), 0.01);
    }

    #[test]
    fn step_decay() {
        let s = Schedule::StepDecay { alpha1: 0.1, factor: 0.1, every: 100 };
        assert_eq!(s.lr_at(0), 0.1);
        assert!((s.lr_at(100) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(250) - 0.001).abs() < 1e-12);
    }
}
