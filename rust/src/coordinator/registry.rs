//! The declarative experiment registry.
//!
//! Every paper artifact (Fig. 2, Tables 1–3, Fig. 3, Thm. 3) is one
//! [`ExperimentSpec`] value here: either a **grid** of [`RunSpec`] cells
//! — (model × schedule × sizing) configurations that the
//! [`super::runner::Runner`] flattens into `cells × seed replicas` work
//! items over the rayon pool — or an **analytic** function for the
//! single-trajectory / closed-form experiments (fig3-precision shares one
//! SGD-LP stream across many accumulators; thm3 is pure simulation).
//!
//! Both the CLI (`swalp reproduce`) and the paper-figure benches resolve
//! experiments exclusively through [`find`]/[`all`] — there is no other
//! dispatch path.

use anyhow::Result;

use crate::coordinator::SwaAccumulator;
use crate::data::{self, loader::Loader};
use crate::quant::{fixed::quantize_fixed, QuantFormat};
use crate::sim;

use super::experiment::Ctx;
use super::report::Cell;
use super::schedule::Schedule;

/// One registered paper experiment.
pub struct ExperimentSpec {
    pub id: &'static str,
    pub title: &'static str,
    /// Paper-expectation commentary, rendered under the table and stored
    /// in the report's `notes` field.
    pub notes: &'static str,
    pub kind: ExpKind,
}

pub enum ExpKind {
    /// A (model × schedule) grid; the Runner executes every cell × seed
    /// replica concurrently and aggregates mean/std per cell.
    Grid {
        cells: fn(&Ctx) -> Vec<RunSpec>,
        /// Report-level reference scalars (e.g. ‖Q(w*)−w*‖²).
        extras: Option<fn(&Ctx) -> Result<Vec<(String, f64)>>>,
    },
    /// Produces finished report cells directly (runs on the calling
    /// thread; kernels inside still parallelize).
    Analytic(fn(&Ctx) -> Result<Vec<Cell>>),
}

/// Step budget of one grid cell.
#[derive(Clone, Debug)]
pub enum Sizing {
    /// Absolute step counts.
    Steps { steps: u64, warmup: u64 },
    /// Epoch counts, translated through the cell's steps-per-epoch.
    Epochs { warmup: u64, avg: u64 },
}

/// Averaging cycle length `c` of one grid cell.
#[derive(Clone, Debug)]
pub enum CyclePolicy {
    Steps(u64),
    /// `f` averages per epoch (cycle = steps-per-epoch / f).
    PerEpoch(u64),
}

/// Learning-rate schedule of one grid cell (warm-up length is resolved
/// from [`Sizing`] at run time).
#[derive(Clone, Debug)]
pub enum SchedSpec {
    Const(f64),
    /// [`Schedule::swalp_paper`]: budget decay during warm-up, then the
    /// constant averaging LR.
    SwalpPaper { alpha1: f64, swa_lr: f64 },
    /// Step decay during warm-up (decay every `warmup / every_div`
    /// steps), then the constant averaging LR.
    SwalpStep { alpha1: f64, factor: f64, every_div: u64, swa_lr: f64 },
}

impl SchedSpec {
    pub fn resolve(&self, warmup: u64) -> Schedule {
        match *self {
            SchedSpec::Const(a) => Schedule::Constant(a),
            SchedSpec::SwalpPaper { alpha1, swa_lr } => {
                Schedule::swalp_paper(alpha1, warmup, swa_lr)
            }
            SchedSpec::SwalpStep { alpha1, factor, every_div, swa_lr } => Schedule::Swalp {
                inner: Box::new(Schedule::StepDecay {
                    alpha1,
                    factor,
                    every: (warmup / every_div.max(1)).max(1),
                }),
                warmup,
                swa_lr,
            },
        }
    }
}

/// Training data of one grid cell.
#[derive(Clone, Debug)]
pub enum DataSpec {
    /// `data::build(model.spec().dataset, seed, scale)`.
    Model { seed: u64, scale: f64 },
    /// `synth::linreg_problem(d, n, seed)` with ‖w − w*‖² tracking
    /// against the empirical optimum (Fig. 2 left).
    LinregWstar { d: usize, n: usize, seed: u64 },
}

/// What a cell's report metrics are computed from after training.
#[derive(Clone, Copy, Debug)]
pub enum EvalKind {
    /// `sgd_err` / `swalp_err` / `gain` (%) from the final test eval.
    TestErr,
    /// Final ‖w−w*‖² of the tracked iterate (plus the distance curve,
    /// the quantization-noise ratio and the Theorem-1 tail slope).
    DistSq,
    /// ‖∇f‖² of the full-precision objective at the LP iterate and at
    /// the weight average (Fig. 2 middle / Theorem 2).
    GradNorm,
    /// Train + test error for both the iterate and the average
    /// (Fig. 2 right / Table 4).
    TrainTestErr,
    /// SWA test error after one averaging epoch and at the end
    /// (Fig. 3 left / Table 5).
    SwaTrajectory,
    /// `sgd_ppl` / `swalp_ppl` / `gain` from the final test eval of a
    /// token-level task: `exp(mean per-token CE)` (the `lm` experiment).
    Perplexity,
}

/// One grid cell: a fully-specified training configuration whose seed
/// replicas the Runner shards across the pool.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub id: String,
    /// Ordered table label columns.
    pub labels: Vec<(String, String)>,
    /// Model registry name (the quantization config is part of the name).
    pub model: String,
    pub data: DataSpec,
    pub sizing: Sizing,
    pub sched: SchedSpec,
    pub cycle: CyclePolicy,
    pub enable_swa: bool,
    /// Seed replicas for this cell (mean/std aggregation).
    pub seeds: u64,
    /// Replica `s` initializes with `init_seed + s` …
    pub init_seed: u64,
    /// … and shuffles batches with `data_seed + s`.
    pub data_seed: u64,
    pub eval: EvalKind,
}

impl RunSpec {
    pub fn new(
        id: &str,
        model: &str,
        data: DataSpec,
        sizing: Sizing,
        sched: SchedSpec,
        eval: EvalKind,
    ) -> RunSpec {
        RunSpec {
            id: id.to_string(),
            labels: vec![],
            model: model.to_string(),
            data,
            sizing,
            sched,
            cycle: CyclePolicy::Steps(1),
            enable_swa: true,
            seeds: 1,
            init_seed: 1,
            data_seed: 100,
            eval,
        }
    }

    pub fn labels(mut self, labels: &[(&str, &str)]) -> Self {
        self.labels = labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        self
    }

    pub fn cycle(mut self, cycle: CyclePolicy) -> Self {
        self.cycle = cycle;
        self
    }

    pub fn swa(mut self, on: bool) -> Self {
        self.enable_swa = on;
        self
    }

    pub fn seeds(mut self, n: u64) -> Self {
        self.seeds = n.max(1);
        self
    }

    /// Canonical JSON identity of this cell for ledger `CellKey`s: every
    /// field that shapes the training outcome is included, the replica
    /// count (`seeds`) is not — the per-item replica index is hashed in
    /// separately by [`crate::ledger::CellKey::new`], so raising
    /// `--seeds` later reuses the replicas a ledger already holds.
    pub fn key_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        let data = match self.data {
            DataSpec::Model { seed, scale } => Value::obj(vec![
                ("kind", Value::str("model")),
                ("seed", Value::Num(seed as f64)),
                ("scale", Value::Num(scale)),
            ]),
            DataSpec::LinregWstar { d, n, seed } => Value::obj(vec![
                ("kind", Value::str("linreg_wstar")),
                ("d", Value::Num(d as f64)),
                ("n", Value::Num(n as f64)),
                ("seed", Value::Num(seed as f64)),
            ]),
        };
        let sizing = match self.sizing {
            Sizing::Steps { steps, warmup } => Value::obj(vec![
                ("kind", Value::str("steps")),
                ("steps", Value::Num(steps as f64)),
                ("warmup", Value::Num(warmup as f64)),
            ]),
            Sizing::Epochs { warmup, avg } => Value::obj(vec![
                ("kind", Value::str("epochs")),
                ("warmup", Value::Num(warmup as f64)),
                ("avg", Value::Num(avg as f64)),
            ]),
        };
        let sched = match self.sched {
            SchedSpec::Const(a) => {
                Value::obj(vec![("kind", Value::str("const")), ("alpha", Value::Num(a))])
            }
            SchedSpec::SwalpPaper { alpha1, swa_lr } => Value::obj(vec![
                ("kind", Value::str("swalp_paper")),
                ("alpha1", Value::Num(alpha1)),
                ("swa_lr", Value::Num(swa_lr)),
            ]),
            SchedSpec::SwalpStep { alpha1, factor, every_div, swa_lr } => Value::obj(vec![
                ("kind", Value::str("swalp_step")),
                ("alpha1", Value::Num(alpha1)),
                ("factor", Value::Num(factor)),
                ("every_div", Value::Num(every_div as f64)),
                ("swa_lr", Value::Num(swa_lr)),
            ]),
        };
        let cycle = match self.cycle {
            CyclePolicy::Steps(c) => Value::obj(vec![
                ("kind", Value::str("steps")),
                ("c", Value::Num(c as f64)),
            ]),
            CyclePolicy::PerEpoch(f) => Value::obj(vec![
                ("kind", Value::str("per_epoch")),
                ("f", Value::Num(f as f64)),
            ]),
        };
        Value::obj(vec![
            ("id", Value::str(&self.id)),
            (
                "labels",
                Value::Arr(
                    self.labels
                        .iter()
                        .map(|(k, v)| Value::Arr(vec![Value::str(k), Value::str(v)]))
                        .collect(),
                ),
            ),
            ("model", Value::str(&self.model)),
            ("data", data),
            ("sizing", sizing),
            ("sched", sched),
            ("cycle", cycle),
            ("enable_swa", Value::Bool(self.enable_swa)),
            ("init_seed", Value::Num(self.init_seed as f64)),
            ("data_seed", Value::Num(self.data_seed as f64)),
            ("eval", Value::str(&format!("{:?}", self.eval))),
        ])
    }
}

/// All registered experiments, in paper order.
pub fn all() -> &'static [ExperimentSpec] {
    &SPECS
}

/// Registered experiment ids, in paper order.
pub fn ids() -> Vec<&'static str> {
    SPECS.iter().map(|s| s.id).collect()
}

pub fn find(id: &str) -> Option<&'static ExperimentSpec> {
    SPECS.iter().find(|s| s.id == id)
}

static SPECS: [ExperimentSpec; 11] = [
    ExperimentSpec {
        id: "fig2-linreg",
        title: "Fig 2 (left): linear regression, fixed point W8F6",
        notes: "expected: SWALP final distance ≪ SGD-LP; tail_slope ≈ -1 (Theorem 1); \
                vs_qnoise compares against the ‖Q(w*)−w*‖² reference",
        kind: ExpKind::Grid { cells: fig2_linreg_cells, extras: Some(fig2_linreg_extras) },
    },
    ExperimentSpec {
        id: "fig2-logreg",
        title: "Fig 2 (middle): logistic regression (MNIST-like), W4F2",
        notes: "expected ordering: SWALP grad_avg ≪ SGD-LP grad_iter; SWALP hits a small \
                noise ball (M≠0, Theorem 2) while SWA-FL keeps shrinking",
        kind: ExpKind::Grid { cells: fig2_logreg_cells, extras: None },
    },
    ExperimentSpec {
        id: "fig2-bits",
        title: "Fig 2 (right) / Table 4: logreg precision sweep",
        notes: "expected shape: SWALP matches float with ~half the fractional bits that \
                SGD-LP needs (Theorem 2's δ² vs δ)",
        kind: ExpKind::Grid { cells: fig2_bits_cells, extras: None },
    },
    ExperimentSpec {
        id: "table1",
        title: "Table 1: test error (%) — float vs 8-bit big/small-block BFP",
        notes: "expected orderings (paper): small-block < big-block; SWALP < SGD-LP within \
                each format; 8-bit small-block SWALP ≈ float SGD",
        kind: ExpKind::Grid { cells: table1_cells, extras: None },
    },
    ExperimentSpec {
        id: "table2",
        title: "Table 2: ImageNet-like ResNet-mini, top-1 error (%)",
        notes: "expected shape: LP gap ≫ FP gap; SWALP recovers a large share of it, more \
                averaging (+3 ep, 50x/ep) helps monotonically",
        kind: ExpKind::Grid { cells: table2_cells, extras: None },
    },
    ExperimentSpec {
        id: "table3",
        title: "Table 3: WAGE-style CNN on CIFAR10-like",
        notes: "expected: WAGE-SWALP < WAGE (SWALP composes with an existing LP scheme)",
        kind: ExpKind::Grid { cells: table3_cells, extras: None },
    },
    ExperimentSpec {
        id: "fig3-frequency",
        title: "Fig 3 (left) / Table 5: averaging frequency",
        notes: "expected: higher frequency converges faster early (after_1_epoch); final \
                errors match (paper Fig 3 left / Table 5)",
        kind: ExpKind::Grid { cells: fig3_frequency_cells, extras: None },
    },
    ExperimentSpec {
        id: "fig3-precision",
        title: "Fig 3 (right) / Table 6: averaging precision W_SWA",
        notes: "expected: ≥9 bits ≈ float; 8 bits slight loss; <8 bits degrades fast \
                (paper Fig 3 right / Table 6)",
        kind: ExpKind::Analytic(fig3_precision_cells),
    },
    ExperimentSpec {
        id: "thm3",
        title: "Theorem 3: SGD-LP noise ball Ω(σδ) vs SWALP O(δ²)",
        notes: "expected: ratio_sgd = E[w²]/(σδ) ≳ constant (lower bound, Thm 3); the SWALP \
                column sits orders below and shrinks faster than δ",
        kind: ExpKind::Analytic(thm3_cells),
    },
    ExperimentSpec {
        id: "prn20",
        title: "PreResNet-20 (BatchNorm) on CIFAR10-like: SWALP on a deep native model",
        notes: "expected: SWALP < SGD-LP on the BatchNorm-equipped PreResNet-20; SWA evals \
                renormalize BN statistics from the eval batch (the paper's BN-recompute note)",
        kind: ExpKind::Grid { cells: prn20_cells, extras: None },
    },
    ExperimentSpec {
        id: "lm",
        title: "Transformer LM (Zipf bigrams): SWALP beyond the conv stack",
        notes: "expected: swalp_ppl < sgd_ppl for the BFP8 transformer (averaging washes \
                out weight-quantization + gradient noise); the fp32-SGD row is the \
                full-precision reference floor",
        kind: ExpKind::Grid { cells: lm_cells, extras: None },
    },
];

// ---------------------------------------------------------------------
// Fig. 2 (left) + App. Fig. 4a: linear regression convergence
// ---------------------------------------------------------------------

const FIG2_LINREG_D: usize = 256;
const FIG2_LINREG_SEED: u64 = 7;

fn fig2_linreg_sizes(ctx: &Ctx) -> (usize, u64) {
    // linreg_problem clamps n to ≥ 2d for the normal equations
    let n = ctx.pick(4096, 1024) as usize;
    let steps = ctx.pick(200_000, 8_000);
    (n, steps)
}

fn fig2_linreg_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let (n, steps) = fig2_linreg_sizes(ctx);
    // averaging starts once the iterate sits in its noise ball
    // (the paper's warm-up discipline)
    let warmup = steps / 4;
    [
        ("SGD-FL", "linreg_fp32", false),
        ("SWA-FL", "linreg_fp32", true),
        ("SGD-LP", "linreg_fx86", false),
        ("SWALP", "linreg_fx86", true),
    ]
    .into_iter()
    .map(|(label, model, swa)| {
        RunSpec::new(
            label,
            model,
            DataSpec::LinregWstar { d: FIG2_LINREG_D, n, seed: FIG2_LINREG_SEED },
            Sizing::Steps { steps, warmup },
            SchedSpec::Const(0.002),
            EvalKind::DistSq,
        )
        .labels(&[("run", label)])
        .swa(swa)
        .seeds(ctx.seeds())
    })
    .collect()
}

/// ‖Q(w*) − w*‖² reference line (stochastic quantization of w*).
fn fig2_linreg_extras(ctx: &Ctx) -> Result<Vec<(String, f64)>> {
    let (n, _) = fig2_linreg_sizes(ctx);
    let problem = data::synth::linreg_problem(FIG2_LINREG_D, n, FIG2_LINREG_SEED);
    Ok(vec![("q_wstar_dist".to_string(), q_wstar_dist(&problem.w_star))])
}

/// ‖Q(w*) − w*‖² for the W8F6 format (the quantization noise floor).
pub(super) fn q_wstar_dist(w_star: &[f32]) -> f64 {
    let qws = quantize_fixed(w_star, 8, 6, 1234, true);
    qws.iter().zip(w_star).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum()
}

// ---------------------------------------------------------------------
// Fig. 2 (middle): logistic regression gradient norm
// ---------------------------------------------------------------------

fn fig2_logreg_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let steps = ctx.pick(24_000, 3_000);
    // average only the stationary phase; the paper warms up for a full
    // epoch budget before folding
    let warmup = steps * 2 / 3;
    // the TRAIN-set gradient-norm eval needs ≥ batch_eval (512) samples
    let scale = ctx.scale(1.0, 0.25).max(0.13);
    [
        ("SGD-FL", "logreg_fp32", false),
        ("SWA-FL", "logreg_fp32", true),
        ("SGD-LP", "logreg_fx_f2", false),
        ("SWALP", "logreg_fx_f2", true),
    ]
    .into_iter()
    .map(|(label, model, swa)| {
        RunSpec::new(
            label,
            model,
            DataSpec::Model { seed: 11, scale },
            Sizing::Steps { steps, warmup },
            SchedSpec::Const(0.02),
            EvalKind::GradNorm,
        )
        .labels(&[("run", label)])
        .swa(swa)
        .seeds(ctx.seeds())
    })
    .collect()
}

// ---------------------------------------------------------------------
// Fig. 2 (right) + Table 4: fractional-bit sweep
// ---------------------------------------------------------------------

fn fig2_bits_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let steps = ctx.pick(16_000, 1_024);
    let warmup = steps * 2 / 3;
    // the TRAIN-set error eval needs ≥ batch_eval (512) samples
    let scale = ctx.scale(1.0, 0.25).max(0.13);
    let fls: &[u32] = if ctx.full() { &[2, 4, 6, 8, 10, 12, 14] } else { &[2, 6, 10] };
    let mut cells = vec![("float32".to_string(), "logreg_fp32".to_string())];
    cells.extend(
        fls.iter().map(|f| (format!("FL={f}, WL={}", f + 2), format!("logreg_fx_f{f}"))),
    );
    cells
        .into_iter()
        .map(|(label, model)| {
            RunSpec::new(
                &label,
                &model,
                DataSpec::Model { seed: 11, scale },
                Sizing::Steps { steps, warmup },
                SchedSpec::Const(0.02),
                EvalKind::TrainTestErr,
            )
            .labels(&[("format", label.as_str())])
            .seeds(ctx.seeds())
        })
        .collect()
}

// ---------------------------------------------------------------------
// Table 1: CIFAR-like × {VGG-mini, PreResNet-mini} × formats
// ---------------------------------------------------------------------

fn table1_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let scale = ctx.scale(0.5, 0.15);
    let warmup = ctx.pick(8, 2);
    let avg = ctx.pick(4, 1);
    let mut cells = Vec::new();
    for ds in ["cifar10", "cifar100"] {
        for (mname, alpha1) in [("vgg", 0.05), ("prn", 0.1)] {
            for fmt in ["fp32", "bfp8big", "bfp8small"] {
                let model = format!("{ds}_{mname}_{fmt}");
                cells.push(
                    RunSpec::new(
                        &model,
                        &model,
                        DataSpec::Model { seed: 21, scale },
                        Sizing::Epochs { warmup, avg },
                        SchedSpec::SwalpPaper { alpha1, swa_lr: 0.01 },
                        EvalKind::TestErr,
                    )
                    .labels(&[("dataset", ds), ("model", mname), ("format", fmt)])
                    // average once per epoch (paper default)
                    .cycle(CyclePolicy::PerEpoch(1))
                    .seeds(ctx.seeds()),
                );
            }
        }
    }
    cells
}

// ---------------------------------------------------------------------
// Table 2: ImageNet-like ResNet
// ---------------------------------------------------------------------

fn table2_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let scale = ctx.scale(0.5, 0.15);
    let warmup = ctx.pick(6, 2);
    [
        ("SGD", "fp32", false, 0, 1),
        ("SWA", "fp32", true, 1, 1),
        ("SGD-LP", "bfp8small", false, 0, 1),
        ("SWALP (+1 ep)", "bfp8small", true, 1, 1),
        ("SWALP (+3 ep)", "bfp8small", true, 3, 1),
        ("SWALP† (50x/ep)", "bfp8small", true, 3, 50),
    ]
    .into_iter()
    .map(|(label, fmt, swa, extra, freq)| {
        RunSpec::new(
            label,
            &format!("imagenet_rn_{fmt}"),
            DataSpec::Model { seed: 31, scale },
            Sizing::Epochs { warmup, avg: extra },
            SchedSpec::SwalpStep { alpha1: 0.1, factor: 0.1, every_div: 3, swa_lr: 0.01 },
            EvalKind::TestErr,
        )
        .labels(&[("run", label)])
        .cycle(CyclePolicy::PerEpoch(freq))
        .swa(swa)
        .seeds(ctx.seeds())
    })
    .collect()
}

// ---------------------------------------------------------------------
// Table 3 (App. F): WAGE-style network ± SWALP
// ---------------------------------------------------------------------

fn table3_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let scale = ctx.scale(0.5, 0.15);
    let warmup = ctx.pick(10, 4);
    let avg = ctx.pick(4, 2);
    // WAGE trains with a large LR on the coarse 2-bit grid (paper: 8 ->
    // decay; SWALP variant: constant 8 then SWA LR 6), scaled for the
    // mini network.
    [("WAGE", false, 0.25), ("WAGE-SWALP", true, 1.5)]
        .into_iter()
        .map(|(label, swa, swa_lr)| {
            RunSpec::new(
                label,
                "wage_cnn",
                DataSpec::Model { seed: 41, scale },
                Sizing::Epochs { warmup, avg },
                SchedSpec::SwalpStep { alpha1: 2.0, factor: 0.5, every_div: 2, swa_lr },
                EvalKind::TestErr,
            )
            .labels(&[("run", label)])
            .swa(swa)
            .seeds(ctx.seeds())
        })
        .collect()
}

// ---------------------------------------------------------------------
// Fig. 3 (left) + Table 5: averaging frequency
// ---------------------------------------------------------------------

fn fig3_frequency_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let scale = ctx.scale(0.5, 0.15);
    let warmup = ctx.pick(8, 3);
    let avg = ctx.pick(4, 2);
    // averages per epoch, mirroring Table 5's 1x .. every-batch sweep
    let freqs: &[u64] = if ctx.full() { &[1, 2, 8, 32] } else { &[1, 8] };
    freqs
        .iter()
        .map(|&f| {
            let label = format!("{f}");
            RunSpec::new(
                &label,
                "cifar100_vgg_bfp8small",
                DataSpec::Model { seed: 51, scale },
                Sizing::Epochs { warmup, avg },
                SchedSpec::SwalpPaper { alpha1: 0.05, swa_lr: 0.01 },
                EvalKind::SwaTrajectory,
            )
            .labels(&[("avg/epoch", label.as_str())])
            .cycle(CyclePolicy::PerEpoch(f))
            .seeds(ctx.seeds())
        })
        .collect()
}

// ---------------------------------------------------------------------
// Fig. 3 (right) + Table 6: averaging precision (Q_SWA sweep)
// ---------------------------------------------------------------------

fn fig3_precision_cells(ctx: &Ctx) -> Result<Vec<Cell>> {
    let model = ctx.load("cifar100_vgg_bfp8small")?;
    let split = data::build(&model.spec().dataset, 61, ctx.scale(0.5, 0.15))?;
    let spe = (split.train.n / model.spec().batch_train).max(1) as u64;
    let warmup = ctx.pick(8, 3) * spe;
    let steps = warmup + ctx.pick(4, 2) * spe;
    let trainer = crate::coordinator::Trainer::new(&*model, &split);

    // One training trajectory, many accumulators: the SGD-LP stream is
    // identical across W_SWA, so fold the same weights into one
    // accumulator per precision (float + 16..6 bits).
    let wls: &[u32] = if ctx.full() { &[16, 14, 12, 10, 9, 8, 7, 6] } else { &[16, 8, 6] };
    let mut accs: Vec<(String, SwaAccumulator)> =
        vec![("float".to_string(), SwaAccumulator::new(None))];
    for &w in wls {
        accs.push((format!("{w}"), SwaAccumulator::new(Some(QuantFormat::bfp(w, true)))));
    }

    let mut ms = model.init(1)?;
    let mut loader = Loader::new(&split.train, model.spec().batch_train, 9);
    let sched = Schedule::swalp_paper(0.05, warmup, 0.01);
    for step in 0..steps {
        let lr = sched.lr_at(step) as f32;
        let (x, y) = loader.next_batch();
        let (x, y) = (x.to_vec(), y.to_vec());
        model.train_step(&mut ms, &x, &y, lr, step)?;
        if step >= warmup && (step - warmup) % spe.min(8) == 0 {
            for (_, acc) in accs.iter_mut() {
                acc.fold(&ms.trainable)?;
            }
        }
    }

    let mut cells = Vec::new();
    for (label, acc) in &accs {
        let avg = acc.average()?;
        let out = if label == "float" {
            trainer.eval_swa(&avg, &ms.state, true)?
        } else {
            // paper: inference activations quantized to W_SWA too
            let wl: f32 = label.parse().unwrap();
            let be = model.spec().batch_eval;
            let mut cursor = 0usize;
            let (mut xb, mut yb) = (Vec::new(), Vec::new());
            let (mut loss, mut metric, mut batches, mut samples) = (0.0, 0.0, 0usize, 0usize);
            while Loader::eval_batch(&split.test, be, &mut cursor, &mut xb, &mut yb) {
                let o = model.eval_flex(&avg, &ms.state, &xb, &yb, wl)?;
                loss += o.loss;
                metric += o.metric;
                batches += 1;
                samples += be;
            }
            crate::runtime::EvalOut {
                loss: loss / batches.max(1) as f64,
                metric: metric / samples.max(1) as f64,
                grad_norm_sq: None,
            }
        };
        let err = out.metric * 100.0;
        eprintln!("[fig3-precision] W_SWA={label}: {err:.2}%");
        cells.push(Cell::analytic(label, &[("w_swa", label.as_str())], &[("err", err)]));
    }
    Ok(cells)
}

// ---------------------------------------------------------------------
// PreResNet-20 (BatchNorm): the QLayer-graph deep model, end to end
// ---------------------------------------------------------------------

fn prn20_cells(ctx: &Ctx) -> Vec<RunSpec> {
    let scale = ctx.scale(0.5, 0.1);
    let warmup = ctx.pick(8, 2);
    let avg = ctx.pick(4, 1);
    [
        ("SGD-LP", "cifar10_prn20_bfp8small", false),
        ("SWALP", "cifar10_prn20_bfp8small", true),
    ]
    .into_iter()
    .map(|(label, model, swa)| {
        RunSpec::new(
            label,
            model,
            DataSpec::Model { seed: 71, scale },
            Sizing::Epochs { warmup, avg },
            SchedSpec::SwalpPaper { alpha1: 0.1, swa_lr: 0.01 },
            EvalKind::TestErr,
        )
        .labels(&[("run", label)])
        // average once per epoch (paper default)
        .cycle(CyclePolicy::PerEpoch(1))
        .swa(swa)
        .seeds(ctx.seeds())
    })
    .collect()
}

// ---------------------------------------------------------------------
// Transformer LM: SWALP on the attention/LayerNorm/embedding stack
// ---------------------------------------------------------------------

fn lm_cells(ctx: &Ctx) -> Vec<RunSpec> {
    // step-sized (not epoch-sized) so the averaging window stays long at
    // every tier: the SWALP-vs-SGD-LP ordering needs the iterate in its
    // constant-LR noise ball before folding starts
    let steps = ctx.pick(6_000, 640);
    let warmup = ctx.pick(4_000, 384);
    let scale = ctx.scale(0.5, 0.1);
    [
        ("SGD-FL", "lm_fp32", false),
        ("SGD-LP", "lm_bfp8small", false),
        ("SWALP", "lm_bfp8small", true),
    ]
    .into_iter()
    .map(|(label, model, swa)| {
        RunSpec::new(
            label,
            model,
            DataSpec::Model { seed: 81, scale },
            Sizing::Steps { steps, warmup },
            SchedSpec::SwalpPaper { alpha1: 0.2, swa_lr: 0.07 },
            EvalKind::Perplexity,
        )
        .labels(&[("run", label)])
        .cycle(CyclePolicy::Steps(ctx.pick(8, 8)))
        .swa(swa)
        .seeds(ctx.seeds())
    })
    .collect()
}

// ---------------------------------------------------------------------
// Theorem 3: pure-simulation noise-ball scaling (no backend needed)
// ---------------------------------------------------------------------

fn thm3_cells(ctx: &Ctx) -> Result<Vec<Cell>> {
    let steps = ctx.pick(1_000_000, 200_000) as usize;
    let sigma = 0.1;
    let alpha = 0.05;
    let deltas: &[f64] = if ctx.full() {
        &[0.1, 0.05, 0.025, 0.0125, 0.00625, 0.003125]
    } else {
        &[0.1, 0.025, 0.00625]
    };
    let mut cells = Vec::new();
    for (i, &d) in deltas.iter().enumerate() {
        let r = sim::noise_ball_1d(alpha, sigma, d, steps, 1, 42 + i as u64);
        let id = format!("{d:.5}");
        cells.push(Cell::analytic(
            &id,
            &[("delta", id.as_str())],
            &[
                ("sgd_lp", r.sgd_lp_second_moment),
                ("ratio_sgd", r.sgd_lp_second_moment / (sigma * d)),
                ("swalp", r.swalp_sq),
                ("ratio_swalp", r.swalp_sq / (d * d)),
            ],
        ));
    }
    Ok(cells)
}
