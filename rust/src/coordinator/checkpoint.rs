//! Checkpointing: save/restore the full training state (trainable
//! weights, BN state, momentum, SWA accumulator, step counter) so long
//! runs survive restarts and trained models can be shipped.
//!
//! Format: a small self-describing binary — magic, version, then a JSON
//! header (names/shapes/sections) followed by raw little-endian f32/f64
//! payloads. No external dependencies (the offline image has no
//! serde/npz), and the header keeps it debuggable.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::quant::{
    self,
    spec::{is_per_tensor, Role},
    QuantFormat,
};
use crate::runtime::ModelState;
use crate::tensor::{NamedTensors, Tensor};
use crate::util::json::{self, Value};

const MAGIC: &[u8; 8] = b"SWALPCK1";

/// The raw f64 SWA accumulator payload: (name, values, shape) triples +
/// fold count, exactly as [`super::swa::SwaAccumulator::raw`] holds it.
pub type Swa64 = (Vec<(String, Vec<f64>, Vec<usize>)>, usize);

pub struct Checkpoint {
    pub step: u64,
    /// Native-registry model id (optional header field, absent in files
    /// written before inference serving existed). When present,
    /// `swalp infer` resolves the backend without a `--model` override.
    pub model: Option<String>,
    pub trainable: NamedTensors,
    pub state: NamedTensors,
    pub momentum: NamedTensors,
    /// SWA average squeezed to f32 + fold count, if averaging started.
    /// Kept for export/eval and for checkpoints written before `swa64`
    /// existed; restoring the accumulator from it is lossy.
    pub swa: Option<(NamedTensors, usize)>,
    /// The accumulator's exact f64 payload (optional section, absent in
    /// older files). When present, resume continues the running mean
    /// bit-for-bit — required for mid-averaging checkpoint-resume to
    /// reproduce an uninterrupted run exactly.
    pub swa64: Option<Swa64>,
    /// SQWA-style deployment section (Shin et al., arXiv:2002.00343):
    /// the SWA average quantized onto the model's Q_W grid at save time
    /// (`swalp train --export-qswa`), so the low-precision deployment
    /// weights ship inside the checkpoint and the fp32-SWA vs
    /// quantized-SWA accuracy gap is measurable at serve time.
    pub qswa: Option<NamedTensors>,
}

/// SQWA-style deployment quantization: the SWA average pushed onto the
/// model's weight grid with nearest (deterministic) rounding — stochastic
/// rounding is a training-time tool; a deployment artifact must be a
/// pure function of the average.
pub fn quantize_swa(avg: &NamedTensors, w_fmt: &QuantFormat) -> NamedTensors {
    let fmt = w_fmt.nearest();
    avg.iter()
        .map(|(n, t)| (n.clone(), quant::apply_format(&fmt, t, 0, Role::Weight, is_per_tensor(n))))
        .collect()
}

fn section_json(ts: &NamedTensors) -> Value {
    Value::Arr(
        ts.iter()
            .map(|(n, t)| {
                Value::obj(vec![
                    ("name", Value::str(n)),
                    (
                        "shape",
                        Value::Arr(t.shape.iter().map(|&d| Value::Num(d as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

fn write_f32s(out: &mut impl Write, ts: &NamedTensors) -> Result<()> {
    for (_, t) in ts {
        for v in &t.data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn section64_json(avg: &[(String, Vec<f64>, Vec<usize>)]) -> Value {
    Value::Arr(
        avg.iter()
            .map(|(n, _, shape)| {
                Value::obj(vec![
                    ("name", Value::str(n)),
                    (
                        "shape",
                        Value::Arr(shape.iter().map(|&d| Value::Num(d as f64)).collect()),
                    ),
                ])
            })
            .collect(),
    )
}

fn write_f64s(out: &mut impl Write, avg: &[(String, Vec<f64>, Vec<usize>)]) -> Result<()> {
    for (_, data, _) in avg {
        for v in data {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_section64(
    inp: &mut impl Read,
    spec: &Value,
) -> Result<Vec<(String, Vec<f64>, Vec<usize>)>> {
    let mut out = Vec::new();
    for item in spec.as_arr()? {
        let name = item.get("name")?.as_str()?.to_string();
        let shape = item.get("shape")?.as_shape()?;
        let n: usize = shape.iter().product();
        let mut data = vec![0f64; n];
        let mut buf = [0u8; 8];
        for v in data.iter_mut() {
            inp.read_exact(&mut buf)?;
            *v = f64::from_le_bytes(buf);
        }
        out.push((name, data, shape));
    }
    Ok(out)
}

fn read_section(inp: &mut impl Read, spec: &Value) -> Result<NamedTensors> {
    let mut out = Vec::new();
    for item in spec.as_arr()? {
        let name = item.get("name")?.as_str()?.to_string();
        let shape = item.get("shape")?.as_shape()?;
        let n: usize = shape.iter().product();
        let mut data = vec![0f32; n];
        let mut buf = [0u8; 4];
        for v in data.iter_mut() {
            inp.read_exact(&mut buf)?;
            *v = f32::from_le_bytes(buf);
        }
        out.push((name, Tensor::new(shape, data)?));
    }
    Ok(out)
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Value::obj(vec![
            ("step", Value::Num(self.step as f64)),
            (
                "model",
                match &self.model {
                    None => Value::Null,
                    Some(m) => Value::str(m),
                },
            ),
            ("trainable", section_json(&self.trainable)),
            ("state", section_json(&self.state)),
            ("momentum", section_json(&self.momentum)),
            (
                "swa",
                match &self.swa {
                    None => Value::Null,
                    Some((ts, m)) => Value::obj(vec![
                        ("m", Value::Num(*m as f64)),
                        ("tensors", section_json(ts)),
                    ]),
                },
            ),
            (
                "swa64",
                match &self.swa64 {
                    None => Value::Null,
                    Some((avg, m)) => Value::obj(vec![
                        ("m", Value::Num(*m as f64)),
                        ("tensors", section64_json(avg)),
                    ]),
                },
            ),
            (
                "qswa",
                match &self.qswa {
                    None => Value::Null,
                    Some(ts) => section_json(ts),
                },
            ),
        ])
        .to_string();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        write_f32s(&mut f, &self.trainable)?;
        write_f32s(&mut f, &self.state)?;
        write_f32s(&mut f, &self.momentum)?;
        if let Some((ts, _)) = &self.swa {
            write_f32s(&mut f, ts)?;
        }
        if let Some((avg, _)) = &self.swa64 {
            write_f64s(&mut f, avg)?;
        }
        if let Some(ts) = &self.qswa {
            write_f32s(&mut f, ts)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).map_err(|e| anyhow!("open {}: {e}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a SWALP checkpoint", path.display());
        }
        let mut len = [0u8; 8];
        f.read_exact(&mut len)?;
        let mut header = vec![0u8; u64::from_le_bytes(len) as usize];
        f.read_exact(&mut header)?;
        let h = json::parse(std::str::from_utf8(&header)?)?;
        let trainable = read_section(&mut f, h.get("trainable")?)?;
        let state = read_section(&mut f, h.get("state")?)?;
        let momentum = read_section(&mut f, h.get("momentum")?)?;
        let swa = match h.get("swa")? {
            Value::Null => None,
            v => {
                let m = v.get("m")?.as_usize()?;
                Some((read_section(&mut f, v.get("tensors")?)?, m))
            }
        };
        // optional section: checkpoints written before swa64 existed
        // load fine (and resume through the lossy f32 path)
        let swa64 = match h.opt("swa64") {
            None | Some(Value::Null) => None,
            Some(v) => {
                let m = v.get("m")?.as_usize()?;
                Some((read_section64(&mut f, v.get("tensors")?)?, m))
            }
        };
        // optional like swa64: absent in pre-serving checkpoints
        let qswa = match h.opt("qswa") {
            None | Some(Value::Null) => None,
            Some(v) => Some(read_section(&mut f, v)?),
        };
        let model = match h.opt("model") {
            None | Some(Value::Null) => None,
            Some(v) => Some(v.as_str()?.to_string()),
        };
        Ok(Checkpoint {
            step: h.get("step")?.as_usize()? as u64,
            model,
            trainable,
            state,
            momentum,
            swa,
            swa64,
            qswa,
        })
    }

    /// The SWA average as f32 tensors, preferring the exact f64 section
    /// (squeezed per-element, matching `SwaAccumulator::average` without
    /// a quantized-averaging format) over the lossy f32 one. `None` when
    /// the checkpoint carries no average at all.
    pub fn swa_f32(&self) -> Result<Option<NamedTensors>> {
        if let Some((avg, _)) = &self.swa64 {
            let ts = avg
                .iter()
                .map(|(n, d, s)| {
                    Ok((n.clone(), Tensor::new(s.clone(), d.iter().map(|&v| v as f32).collect())?))
                })
                .collect::<Result<NamedTensors>>()?;
            return Ok(Some(ts));
        }
        Ok(self.swa.as_ref().map(|(ts, _)| ts.clone()))
    }

    pub fn from_model_state(step: u64, ms: &ModelState, swa: Option<(NamedTensors, usize)>) -> Self {
        Checkpoint {
            step,
            model: None,
            trainable: ms.trainable.clone(),
            state: ms.state.clone(),
            momentum: ms.momentum.clone(),
            swa,
            swa64: None,
            qswa: None,
        }
    }

    pub fn into_model_state(self) -> ModelState {
        ModelState {
            trainable: self.trainable,
            state: self.state,
            momentum: self.momentum,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn named(name: &str, shape: Vec<usize>, fill: f32) -> (String, Tensor) {
        let n = shape.iter().product();
        (
            name.to_string(),
            Tensor::new(shape, (0..n).map(|i| fill + i as f32).collect()).unwrap(),
        )
    }

    #[test]
    fn roundtrip_full_state() {
        let ck = Checkpoint {
            step: 1234,
            model: Some("mlp_qmm_fx86".into()),
            trainable: vec![named("a.w", vec![2, 3], 0.5), named("b", vec![4], -1.0)],
            state: vec![named("bn.mean", vec![4], 0.0)],
            momentum: vec![named("a.w", vec![2, 3], 9.0), named("b", vec![4], 2.0)],
            swa: Some((vec![named("a.w", vec![2, 3], 7.0), named("b", vec![4], 3.0)], 17)),
            swa64: None,
            qswa: Some(vec![named("a.w", vec![2, 3], 7.5), named("b", vec![4], 3.5)]),
        };
        let dir = std::env::temp_dir().join("swalp_ck_test");
        let path = dir.join("ck.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 1234);
        assert_eq!(back.model.as_deref(), Some("mlp_qmm_fx86"));
        assert_eq!(back.trainable, ck.trainable);
        assert_eq!(back.state, ck.state);
        assert_eq!(back.momentum, ck.momentum);
        let (ts, m) = back.swa.unwrap();
        assert_eq!(m, 17);
        assert_eq!(ts, ck.swa.unwrap().0);
        assert!(back.swa64.is_none());
        assert_eq!(back.qswa, ck.qswa);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn swa64_section_roundtrips_bit_for_bit() {
        // values deliberately NOT f32-representable: the f32 `swa`
        // section cannot carry them, the f64 section must
        let exact = vec![
            ("a.w".to_string(), vec![0.1f64, 1.0 + 1e-12, -3.7e-300], vec![3usize]),
            ("b".to_string(), vec![std::f64::consts::PI], vec![1usize]),
        ];
        let ck = Checkpoint {
            step: 80,
            model: None,
            trainable: vec![named("a.w", vec![3], 0.5)],
            state: vec![],
            momentum: vec![named("a.w", vec![3], 0.0)],
            swa: Some((vec![named("a.w", vec![3], 0.1)], 4)),
            swa64: Some((exact.clone(), 4)),
            qswa: None,
        };
        let dir = std::env::temp_dir().join("swalp_ck_test_swa64");
        let path = dir.join("ck.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let (avg, m) = back.swa64.unwrap();
        assert_eq!(m, 4);
        assert_eq!(avg.len(), exact.len());
        for ((n_a, d_a, s_a), (n_b, d_b, s_b)) in avg.iter().zip(&exact) {
            assert_eq!(n_a, n_b);
            assert_eq!(s_a, s_b);
            for (x, y) in d_a.iter().zip(d_b) {
                assert_eq!(x.to_bits(), y.to_bits(), "f64 payload must be bit-exact");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn swa_f32_prefers_the_exact_f64_section() {
        let ck = Checkpoint {
            step: 1,
            model: None,
            trainable: vec![named("w", vec![2], 0.0)],
            state: vec![],
            momentum: vec![named("w", vec![2], 0.0)],
            // deliberately different values in the lossy f32 section —
            // the f64 squeeze must win
            swa: Some((vec![named("w", vec![2], 100.0)], 2)),
            swa64: Some((vec![("w".to_string(), vec![0.25f64, 0.5], vec![2usize])], 2)),
            qswa: None,
        };
        let ts = ck.swa_f32().unwrap().unwrap();
        assert_eq!(ts[0].1.data, vec![0.25f32, 0.5]);
    }

    #[test]
    fn quantize_swa_is_deterministic_and_on_grid() {
        let avg = vec![named("w", vec![8], 0.123)];
        let fmt = QuantFormat::fixed(8, 6);
        let a = quantize_swa(&avg, &fmt);
        assert_eq!(a, quantize_swa(&avg, &fmt), "deployment export must be deterministic");
        for (_, t) in &a {
            for &v in &t.data {
                assert_eq!(v, (v * 64.0).round() / 64.0, "{v} is off the W8F6 grid");
            }
        }
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("swalp_ck_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors_cleanly() {
        assert!(Checkpoint::load(Path::new("/nonexistent/x.bin")).is_err());
    }
}
