//! The SWA accumulator — Algorithm 1 line 6 / Algorithm 2 step (4).
//!
//! Runs on the host in f64 ("high precision"); the §5.1 quantized-
//! averaging variant re-quantizes the stored average to a W_SWA-bit
//! Small-block BFP after every fold, eliminating high-precision storage
//! (Fig. 3 right / Table 6).

use anyhow::{bail, Result};

use crate::quant::{self, spec::is_per_tensor, spec::Role, QuantFormat};
use crate::rng;
use crate::tensor::{NamedTensors, Tensor};

pub struct SwaAccumulator {
    /// f64 running average per tensor (the "high-precision" store).
    avg: Vec<(String, Vec<f64>, Vec<usize>)>,
    /// number of models folded in so far (the paper's m).
    pub m: usize,
    /// §5.1: quantize the stored average to this format after each fold.
    pub q_swa: Option<QuantFormat>,
}

impl SwaAccumulator {
    pub fn new(q_swa: Option<QuantFormat>) -> Self {
        SwaAccumulator { avg: vec![], m: 0, q_swa }
    }

    /// Restore from a checkpointed f32 average (checkpoint.rs). Lossy —
    /// the f64 accumulator is squeezed through f32 — so resuming
    /// mid-averaging through this path drifts; prefer [`Self::restore_raw`]
    /// with the checkpoint's `swa64` payload when present.
    pub fn restore(tensors: &NamedTensors, m: usize, q_swa: Option<QuantFormat>) -> Self {
        SwaAccumulator {
            avg: tensors
                .iter()
                .map(|(n, t)| {
                    (n.clone(), t.data.iter().map(|&v| v as f64).collect(), t.shape.clone())
                })
                .collect(),
            m,
            q_swa,
        }
    }

    /// The accumulator's exact f64 payload, for lossless checkpointing.
    pub fn raw(&self) -> &[(String, Vec<f64>, Vec<usize>)] {
        &self.avg
    }

    /// Restore from the exact f64 payload ([`Self::raw`]): a resumed run
    /// continues the running mean bit-for-bit where it left off.
    pub fn restore_raw(
        avg: Vec<(String, Vec<f64>, Vec<usize>)>,
        m: usize,
        q_swa: Option<QuantFormat>,
    ) -> Self {
        SwaAccumulator { avg, m, q_swa }
    }

    /// Fold the current low-precision weights into the running average:
    /// w̄ ← (w̄·m + w)/(m+1).
    ///
    /// The update is elementwise, so large tensors fan out over the rayon
    /// pool in contiguous chunks — bit-identical to the serial pass for
    /// any thread count (each element's arithmetic is untouched), which
    /// keeps checkpoint-resume reproducibility intact.
    pub fn fold(&mut self, trainable: &NamedTensors) -> Result<()> {
        if self.m == 0 {
            self.avg = trainable
                .iter()
                .map(|(n, t)| (n.clone(), t.data.iter().map(|&v| v as f64).collect(), t.shape.clone()))
                .collect();
        } else {
            if self.avg.len() != trainable.len() {
                bail!("fold: tensor count changed ({} vs {})", self.avg.len(), trainable.len());
            }
            let m = self.m as f64;
            for ((_, acc, _), (_, t)) in self.avg.iter_mut().zip(trainable) {
                fold_into(acc, &t.data, m);
            }
        }
        self.m += 1;
        if let Some(fmt) = self.q_swa.clone() {
            // quantized averaging: the stored average itself lives in
            // W_SWA-bit BFP (one fold-indexed stochastic event per tensor)
            for (i, (name, acc, shape)) in self.avg.iter_mut().enumerate() {
                let t = Tensor::new(
                    shape.clone(),
                    acc.iter().map(|&v| v as f32).collect(),
                )?;
                let seed = rng::derive_seed(&[self.m as u32, i as u32, 0x5A]);
                let q = quant::apply_format(&fmt, &t, seed, Role::Weight, is_per_tensor(name));
                for (a, &v) in acc.iter_mut().zip(&q.data) {
                    *a = v as f64;
                }
            }
        }
        Ok(())
    }

    /// Materialize the average as f32 tensors (for eval / export).
    pub fn average(&self) -> Result<NamedTensors> {
        if self.m == 0 {
            bail!("average() before any fold");
        }
        self.avg
            .iter()
            .map(|(n, acc, shape)| {
                Ok((n.clone(), Tensor::new(shape.clone(), acc.iter().map(|&v| v as f32).collect())?))
            })
            .collect()
    }

    /// ‖w̄ − w*‖² against a reference flat vector (Fig. 2 left metric).
    /// Only valid for single-tensor models (linreg).
    pub fn sq_dist_to(&self, w_star: &[f32]) -> Result<f64> {
        if self.avg.len() != 1 {
            bail!("sq_dist_to expects a single-tensor model");
        }
        let (_, acc, _) = &self.avg[0];
        if acc.len() != w_star.len() {
            bail!("dim mismatch {} vs {}", acc.len(), w_star.len());
        }
        Ok(acc
            .iter()
            .zip(w_star)
            .map(|(&a, &b)| (a - b as f64).powi(2))
            .sum())
    }
}

/// Elementwise running-mean update, parallel past the threshold where
/// the pool dispatch amortizes.
fn fold_into(acc: &mut [f64], w: &[f32], m: f64) {
    const PAR_MIN: usize = 1 << 16;
    let serial = |acc: &mut [f64], w: &[f32]| {
        for (a, &v) in acc.iter_mut().zip(w) {
            *a = (*a * m + v as f64) / (m + 1.0);
        }
    };
    let threads = rayon::current_num_threads();
    if acc.len() < PAR_MIN || threads <= 1 {
        serial(acc, w);
        return;
    }
    let chunk = acc.len().div_ceil(threads);
    rayon::scope(|s| {
        for (ac, wc) in acc.chunks_mut(chunk).zip(w.chunks(chunk)) {
            let serial = &serial;
            s.spawn(move |_| serial(ac, wc));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn named(vals: &[f32]) -> NamedTensors {
        vec![("w".into(), Tensor::new(vec![vals.len()], vals.to_vec()).unwrap())]
    }

    #[test]
    fn running_mean_matches_batch_mean() {
        let mut acc = SwaAccumulator::new(None);
        let seqs = [[1.0f32, 2.0], [3.0, 4.0], [5.0, 9.0]];
        for s in &seqs {
            acc.fold(&named(s)).unwrap();
        }
        let avg = acc.average().unwrap();
        assert!((avg[0].1.data[0] - 3.0).abs() < 1e-6);
        assert!((avg[0].1.data[1] - 5.0).abs() < 1e-6);
        assert_eq!(acc.m, 3);
    }

    #[test]
    fn quantized_averaging_lands_on_grid() {
        let fmt = QuantFormat::bfp(8, true);
        let mut acc = SwaAccumulator::new(Some(fmt));
        acc.fold(&named(&[0.111, 0.222, 0.333, 0.444])).unwrap();
        let avg = acc.average().unwrap();
        // all values on a power-of-two grid scaled by the block exponent
        let amax = avg[0].1.data.iter().fold(0f32, |m, &v| m.max(v.abs()));
        assert!(amax > 0.0);
        // spacing of an 8-bit BFP grid: delta = 2^(e-6)
        let e = crate::quant::bfp::floor_log2(amax).max(-126);
        let delta = 2f32.powi(e - 6);
        for &v in &avg[0].1.data {
            let k = v / delta;
            assert!((k - k.round()).abs() < 1e-3, "{v} not on grid {delta}");
        }
    }

    #[test]
    fn sq_dist_tracks_convergence() {
        let mut acc = SwaAccumulator::new(None);
        acc.fold(&named(&[1.0, 1.0])).unwrap();
        assert!((acc.sq_dist_to(&[1.0, 1.0]).unwrap()).abs() < 1e-12);
        acc.fold(&named(&[3.0, 3.0])).unwrap();
        // average is (2,2); dist to (1,1) = 2
        assert!((acc.sq_dist_to(&[1.0, 1.0]).unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn average_before_fold_errors() {
        assert!(SwaAccumulator::new(None).average().is_err());
    }

    #[test]
    fn restore_roundtrips_average_and_fold_count() {
        let mut acc = SwaAccumulator::new(None);
        // exactly-representable values, so f64 -> f32 -> f64 is lossless
        acc.fold(&named(&[1.0, -2.0])).unwrap();
        acc.fold(&named(&[3.0, 6.0])).unwrap();
        let avg = acc.average().unwrap();
        let restored = SwaAccumulator::restore(&avg, acc.m, None);
        assert_eq!(restored.m, 2);
        assert_eq!(restored.average().unwrap(), avg);
        assert!(restored.q_swa.is_none());
    }

    #[test]
    fn fold_after_restore_continues_the_running_mean() {
        let mut direct = SwaAccumulator::new(None);
        direct.fold(&named(&[1.0, 2.0])).unwrap();
        direct.fold(&named(&[2.0, 4.0])).unwrap();

        let snapshot = direct.average().unwrap();
        let mut resumed = SwaAccumulator::restore(&snapshot, direct.m, None);

        direct.fold(&named(&[6.0, 12.0])).unwrap();
        resumed.fold(&named(&[6.0, 12.0])).unwrap();

        // mean of (1,2,6) = 3 and (2,4,12) = 6 on both paths
        let a = direct.average().unwrap();
        let b = resumed.average().unwrap();
        assert!((a[0].1.data[0] - 3.0).abs() < 1e-6);
        assert!((a[0].1.data[1] - 6.0).abs() < 1e-6);
        assert!((b[0].1.data[0] - 3.0).abs() < 1e-6);
        assert!((b[0].1.data[1] - 6.0).abs() < 1e-6);
        assert_eq!(direct.m, resumed.m);
    }

    #[test]
    fn restore_raw_resumes_bit_for_bit() {
        let mut direct = SwaAccumulator::new(None);
        // 0.1/0.7 are not exactly representable: their f64 running mean
        // is NOT an f32 value, so the raw path is strictly stronger than
        // the lossy f32 restore
        direct.fold(&named(&[0.1, 0.3])).unwrap();
        direct.fold(&named(&[0.7, 0.9])).unwrap();
        let mut resumed = SwaAccumulator::restore_raw(direct.raw().to_vec(), direct.m, None);
        let lossy = SwaAccumulator::restore(&direct.average().unwrap(), direct.m, None);
        assert_ne!(lossy.raw()[0].1[0].to_bits(), direct.raw()[0].1[0].to_bits());
        direct.fold(&named(&[0.2, 0.4])).unwrap();
        resumed.fold(&named(&[0.2, 0.4])).unwrap();
        assert_eq!(direct.m, resumed.m);
        for ((_, a, _), (_, b, _)) in direct.raw().iter().zip(resumed.raw()) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn restore_preserves_quantized_averaging_mode() {
        let fmt = QuantFormat::bfp(9, true);
        let mut acc = SwaAccumulator::new(Some(fmt.clone()));
        acc.fold(&named(&[0.5, 0.25, 0.125, 1.0])).unwrap();
        let avg = acc.average().unwrap();
        let mut restored = SwaAccumulator::restore(&avg, acc.m, Some(fmt));
        assert!(restored.q_swa.is_some());
        // folding through the restored accumulator still quantizes
        restored.fold(&named(&[0.5, 0.25, 0.125, 1.0])).unwrap();
        assert_eq!(restored.m, 2);
    }
}
