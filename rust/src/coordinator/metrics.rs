//! Run metrics: in-memory series + CSV/JSON export for the experiment
//! harness and EXPERIMENTS.md.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Value;

#[derive(Default)]
pub struct MetricsLog {
    /// (step, series name, value)
    pub rows: Vec<(u64, String, f64)>,
}

impl MetricsLog {
    pub fn log(&mut self, step: u64, key: &str, value: f64) {
        self.rows.push((step, key.to_string(), value));
    }

    /// All (step, value) points of one series, in insertion order.
    pub fn series(&self, key: &str) -> Vec<(u64, f64)> {
        self.rows
            .iter()
            .filter(|(_, k, _)| k == key)
            .map(|(s, _, v)| (*s, *v))
            .collect()
    }

    pub fn last(&self, key: &str) -> Option<f64> {
        self.series(key).last().map(|(_, v)| *v)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,key,value\n");
        for (s, k, v) in &self.rows {
            out.push_str(&format!("{s},{k},{v}\n"));
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::Arr(
            self.rows
                .iter()
                .map(|(s, k, v)| {
                    Value::obj(vec![
                        ("step", Value::Num(*s as f64)),
                        ("key", Value::str(k)),
                        ("value", Value::Num(*v)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_filtering_and_csv() {
        let mut m = MetricsLog::default();
        m.log(0, "loss", 2.0);
        m.log(1, "loss", 1.0);
        m.log(1, "err", 0.5);
        assert_eq!(m.series("loss"), vec![(0, 2.0), (1, 1.0)]);
        assert_eq!(m.last("err"), Some(0.5));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,key,value\n"));
        assert_eq!(csv.lines().count(), 4);
    }
}
