//! Synthetic dataset substrates (DESIGN.md §5).
//!
//! The image (CIFAR/MNIST/ImageNet) datasets are not shipped in this
//! offline image, so each is replaced by a structured synthetic
//! generator with the same shapes, class counts and split discipline.
//! The paper's claims under test are *relative orderings* between
//! training regimes on a fixed data distribution, which these preserve:
//! class-prototype + augmentation noise tasks have the same
//! learnable-signal/noise structure that makes quantization hurt and
//! averaging help.

pub mod images;
pub mod loader;
pub mod synth;
pub mod text;

use anyhow::{bail, Result};

/// An in-memory dataset: `n` samples of `x_shape` with labels/targets of
/// `y_shape` (scalar () for class ids and regression targets).
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub classes: usize,
}

impl Dataset {
    pub fn x_elem(&self) -> usize {
        self.x_shape.iter().product()
    }

    pub fn y_elem(&self) -> usize {
        self.y_shape.iter().product::<usize>().max(1)
    }

    pub fn sample_x(&self, i: usize) -> &[f32] {
        let e = self.x_elem();
        &self.x[i * e..(i + 1) * e]
    }

    pub fn sample_y(&self, i: usize) -> &[f32] {
        let e = self.y_elem();
        &self.y[i * e..(i + 1) * e]
    }
}

/// Train/test pair.
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

/// Build the dataset named in the manifest (`dataset` field), sized for
/// the experiment harness. `scale` scales the default sample counts
/// (benches use scale<1 in --quick mode).
pub fn build(name: &str, seed: u64, scale: f64) -> Result<Split> {
    let sz = |n: usize| ((n as f64 * scale) as usize).max(64);
    // test sets must cover at least one eval batch (batch_eval is 512 for
    // the logreg artifacts, 256 for the image models, 16 for the LM)
    let tz = |n: usize, floor: usize| sz(n).max(floor);
    Ok(match name {
        "linreg_synth" => synth::linreg_split(256, sz(4096), seed),
        "mnist_like" => images::flat_split(784, 10, sz(4096), tz(1024, 512), seed),
        "mnist_like_256" => images::flat_split(256, 10, sz(4096), tz(1024, 512), seed),
        "cifar10_like" => images::image_split(10, sz(4096), tz(1024, 256), seed),
        "cifar100_like" => images::image_split(100, sz(4096), tz(1024, 256), seed),
        "imagenet_like" => images::image_split(20, sz(6144), tz(1024, 256), seed),
        "zipf_lm" => text::zipf_lm_split(64, 64, sz(2048), tz(256, 16), seed),
        other => bail!("unknown dataset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_all() {
        for name in [
            "linreg_synth",
            "mnist_like",
            "mnist_like_256",
            "cifar10_like",
            "cifar100_like",
            "imagenet_like",
            "zipf_lm",
        ] {
            let s = build(name, 7, 0.05).unwrap();
            assert!(s.train.n >= 64, "{name}");
            assert_eq!(s.train.x.len(), s.train.n * s.train.x_elem());
            assert_eq!(s.train.y.len(), s.train.n * s.train.y_elem());
        }
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(build("nope", 0, 1.0).is_err());
    }
}
