//! Class-prototype image/vector generators — the MNIST/CIFAR/ImageNet
//! substitutes (DESIGN.md §5).
//!
//! Each class gets a fixed random prototype; samples are prototype +
//! augmentation (shift/flip for images) + per-sample noise. Train and
//! test draw from identical distributions with disjoint noise, so
//! generalization-gap behaviour (what SWA/SWALP improves) is real.

use crate::rng::StreamRng;

use super::{Dataset, Split};

const HW: usize = 16; // image side (scaled-down CIFAR; DESIGN.md §5)
const CH: usize = 3;

/// Flat-vector classification data (MNIST-like), d features, k classes.
pub fn flat_split(d: usize, k: usize, n_train: usize, n_test: usize, seed: u64) -> Split {
    let mut rng = StreamRng::new(seed ^ 0xF1A7);
    // class overlap tuned so a linear model plateaus at a finite loss
    // (real MNIST is not separable by logreg either) — the quantization
    // noise ball of §4.3 is only visible at a non-degenerate optimum
    let protos: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal() * 0.25).collect())
        .collect();
    let make = |rng: &mut StreamRng, n: usize, name: &str| {
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.below(k);
            for j in 0..d {
                // MNIST-ish: bounded pixel range
                let v = protos[c][j] + rng.normal() * 1.4;
                x.push(v.clamp(-2.5, 2.5) * 0.5);
            }
            y.push(c as f32);
        }
        Dataset {
            name: name.into(),
            n,
            x_shape: vec![d],
            y_shape: vec![],
            x,
            y,
            classes: k,
        }
    };
    let train = make(&mut rng, n_train, "flat_train");
    let test = make(&mut rng, n_test, "flat_test");
    Split { train, test }
}

/// CIFAR-like (CH, HW, HW) images, k classes, with shift/flip/noise
/// augmentation baked into the sample draw (the paper's "standard
/// preprocessing and data augmentation").
pub fn image_split(k: usize, n_train: usize, n_test: usize, seed: u64) -> Split {
    let mut rng = StreamRng::new(seed ^ 0xC1FA);
    let d = CH * HW * HW;
    // smooth-ish prototypes: low-frequency random fields
    let protos: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let mut img = vec![0.0f32; d];
            // sum of a few random blobs per channel
            for c in 0..CH {
                for _ in 0..4 {
                    let cy = rng.uniform_in(2.0, (HW - 2) as f32);
                    let cx = rng.uniform_in(2.0, (HW - 2) as f32);
                    let amp = rng.normal() * 0.9;
                    let rad = rng.uniform_in(1.5, 4.0);
                    for yy in 0..HW {
                        for xx in 0..HW {
                            let dy = yy as f32 - cy;
                            let dx = xx as f32 - cx;
                            let g = (-(dy * dy + dx * dx) / (2.0 * rad * rad)).exp();
                            img[c * HW * HW + yy * HW + xx] += amp * g;
                        }
                    }
                }
            }
            img
        })
        .collect();

    let make = |rng: &mut StreamRng, n: usize, name: &str| {
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let cls = rng.below(k);
            let sy = rng.below(5) as isize - 2; // shift ±2 (random crop)
            let sx = rng.below(5) as isize - 2;
            let flip = rng.uniform() < 0.5;
            for c in 0..CH {
                for yy in 0..HW {
                    for xx in 0..HW {
                        let src_y = yy as isize + sy;
                        let src_x = if flip { HW as isize - 1 - xx as isize } else { xx as isize } + sx;
                        let base = if (0..HW as isize).contains(&src_y)
                            && (0..HW as isize).contains(&src_x)
                        {
                            protos[cls][c * HW * HW + src_y as usize * HW + src_x as usize]
                        } else {
                            0.0
                        };
                        x.push(base + rng.normal() * 0.55);
                    }
                }
            }
            y.push(cls as f32);
        }
        Dataset {
            name: name.into(),
            n,
            x_shape: vec![CH, HW, HW],
            y_shape: vec![],
            x,
            y,
            classes: k,
        }
    };
    let train = make(&mut rng, n_train, "img_train");
    let test = make(&mut rng, n_test, "img_test");
    Split { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_shapes_and_labels() {
        let s = flat_split(64, 10, 256, 128, 1);
        assert_eq!(s.train.x.len(), 256 * 64);
        assert!(s.train.y.iter().all(|&c| (0.0..10.0).contains(&c)));
        assert_eq!(s.test.n, 128);
    }

    #[test]
    fn image_shapes() {
        let s = image_split(10, 128, 64, 2);
        assert_eq!(s.train.x_shape, vec![3, 16, 16]);
        assert_eq!(s.train.x.len(), 128 * 3 * 16 * 16);
    }

    #[test]
    fn classes_are_distinguishable() {
        // nearest-prototype classification on clean prototypes must beat
        // chance by a wide margin — the task has learnable signal
        let s = image_split(10, 512, 128, 3);
        let d = s.train.x_elem();
        // estimate class means from train
        let mut means = vec![vec![0.0f64; d]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..s.train.n {
            let c = s.train.y[i] as usize;
            counts[c] += 1;
            for (m, &v) in means[c].iter_mut().zip(s.train.sample_x(i)) {
                *m += v as f64;
            }
        }
        for c in 0..10 {
            for m in means[c].iter_mut() {
                *m /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..s.test.n {
            let xi = s.test.sample_x(i);
            let mut best = (f64::MAX, 0usize);
            for c in 0..10 {
                let dist: f64 = xi
                    .iter()
                    .zip(&means[c])
                    .map(|(&a, &b)| (a as f64 - b).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == s.test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / s.test.n as f64;
        assert!(acc > 0.5, "nearest-mean acc {acc} — no signal in data");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = flat_split(32, 4, 64, 32, 9);
        let b = flat_split(32, 4, 64, 32, 9);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.y, b.train.y);
    }
}
