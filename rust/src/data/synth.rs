//! Linear-regression synthetic dataset (paper Appendix G) + the exact
//! empirical optimum via a dense Cholesky solve, so Fig. 2 (left) can
//! plot ‖w_t − w*‖² against the true minimizer of the *empirical*
//! objective (the quantity Theorem 1 bounds).

use crate::rng::StreamRng;

use super::{Dataset, Split};

/// App. G: x_i ~ N(0, σ_x² I_d); w_init ~ U[-1,1]^d; y_i ~ N(w_init·x_i, σ_u²),
/// with d = 256, n = 4096, σ_x = σ_u = 1.
pub struct LinRegProblem {
    pub split: Split,
    pub w_init: Vec<f32>,
    /// argmin of the empirical mean-squared error (normal equations).
    pub w_star: Vec<f32>,
}

pub fn linreg_problem(d: usize, n: usize, seed: u64) -> LinRegProblem {
    // the empirical optimum needs an over-determined system
    let n = n.max(2 * d);
    let mut rng = StreamRng::new(seed);
    let w_init: Vec<f32> = (0..d).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let start = x.len();
        let mut dot = 0.0f64;
        for j in 0..d {
            let v = rng.normal();
            x.push(v);
            dot += (v as f64) * (w_init[j] as f64);
        }
        let _ = start;
        y.push((dot + rng.normal() as f64) as f32);
    }
    let w_star = normal_equations(&x, &y, d, n);
    // held-out set from the same generator (used as the eval batch pool)
    let mut xt = Vec::with_capacity(256 * d);
    let mut yt = Vec::with_capacity(256);
    for _ in 0..256 {
        let mut dot = 0.0f64;
        for j in 0..d {
            let v = rng.normal();
            xt.push(v);
            dot += (v as f64) * (w_init[j] as f64);
        }
        yt.push((dot + rng.normal() as f64) as f32);
    }
    LinRegProblem {
        split: Split {
            train: Dataset {
                name: "linreg_synth".into(),
                n,
                x_shape: vec![d],
                y_shape: vec![],
                x,
                y,
                classes: 0,
            },
            test: Dataset {
                name: "linreg_synth".into(),
                n: 256,
                x_shape: vec![d],
                y_shape: vec![],
                x: xt,
                y: yt,
                classes: 0,
            },
        },
        w_init,
        w_star,
    }
}

pub fn linreg_split(d: usize, n: usize, seed: u64) -> Split {
    linreg_problem(d, n, seed).split
}

/// Solve (XᵀX) w = Xᵀy by Cholesky (the objective is (1/n)Σ(w·x−y)²; the
/// 1/n cancels). X is row-major n×d.
pub fn normal_equations(x: &[f32], y: &[f32], d: usize, n: usize) -> Vec<f32> {
    // a = XᵀX (d×d, symmetric), b = Xᵀy
    let mut a = vec![0.0f64; d * d];
    let mut b = vec![0.0f64; d];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        let yi = y[i] as f64;
        for p in 0..d {
            let xp = row[p] as f64;
            b[p] += xp * yi;
            for q in p..d {
                a[p * d + q] += xp * row[q] as f64;
            }
        }
    }
    for p in 0..d {
        for q in 0..p {
            a[p * d + q] = a[q * d + p];
        }
    }
    // tiny ridge for numerical safety (f32-sourced Gram matrices can sit
    // on the PD boundary)
    let trace: f64 = (0..d).map(|p| a[p * d + p]).sum();
    let ridge = 1e-9 * trace / d as f64;
    for p in 0..d {
        a[p * d + p] += ridge;
    }
    cholesky_solve(&mut a, &mut b, d);
    b.into_iter().map(|v| v as f32).collect()
}

/// In-place Cholesky A = LLᵀ then two triangular solves; `a` is destroyed
/// and `b` becomes the solution. Panics if A is not positive definite
/// (cannot happen for XᵀX with n ≫ d and continuous data).
pub fn cholesky_solve(a: &mut [f64], b: &mut [f64], d: usize) {
    // decompose (lower triangle in place)
    for j in 0..d {
        for k in 0..j {
            let ljk = a[j * d + k];
            for i in j..d {
                a[i * d + j] -= a[i * d + k] * ljk;
            }
        }
        let diag = a[j * d + j];
        assert!(diag > 0.0, "matrix not positive definite at {j} ({diag})");
        let inv = 1.0 / diag.sqrt();
        for i in j..d {
            a[i * d + j] *= inv;
        }
    }
    // L z = b
    for i in 0..d {
        let mut s = b[i];
        for k in 0..i {
            s -= a[i * d + k] * b[k];
        }
        b[i] = s / a[i * d + i];
    }
    // Lᵀ w = z
    for i in (0..d).rev() {
        let mut s = b[i];
        for k in (i + 1)..d {
            s -= a[k * d + i] * b[k];
        }
        b[i] = s / a[i * d + i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_small_system() {
        // A = [[4,2],[2,3]], b = [10, 8] -> w = [1.75, 1.5]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 8.0];
        cholesky_solve(&mut a, &mut b, 2);
        assert!((b[0] - 1.75).abs() < 1e-12);
        assert!((b[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn w_star_is_near_w_init_with_low_noise() {
        let p = linreg_problem(32, 2048, 3);
        // with n >> d and unit noise, w* ≈ w_init to within ~1/sqrt(n)
        let dist: f64 = p
            .w_star
            .iter()
            .zip(&p.w_init)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(dist < 0.5, "‖w*-w_init‖² = {dist}");
    }

    #[test]
    fn w_star_beats_w_init_on_training_loss() {
        let p = linreg_problem(16, 512, 9);
        let ds = &p.split.train;
        let loss = |w: &[f32]| -> f64 {
            (0..ds.n)
                .map(|i| {
                    let xi = ds.sample_x(i);
                    let pred: f64 = xi
                        .iter()
                        .zip(w)
                        .map(|(&a, &b)| (a as f64) * (b as f64))
                        .sum();
                    (pred - ds.y[i] as f64).powi(2)
                })
                .sum::<f64>()
                / ds.n as f64
        };
        assert!(loss(&p.w_star) <= loss(&p.w_init) + 1e-9);
    }
}
