//! Synthetic Zipf-bigram language-modeling corpus for the end-to-end
//! transformer example: a Markov chain whose unigram distribution is
//! Zipfian and whose bigram structure is deterministic-with-noise, so a
//! model that learns the transitions gets a large loss drop over the
//! unigram entropy floor.

use crate::rng::StreamRng;

use super::{Dataset, Split};

/// Per-split stream tags: each split draws from its own `StreamRng`, so
/// the test corpus is a function of `(seed, n_test)` alone — resizing
/// the train split (e.g. `--quick` scaling) can never shift the test
/// tokens (pinned by `tests/prop_invariants.rs`).
const TRAIN_STREAM: u64 = 0x217F;
const TEST_STREAM: u64 = 0x7E57_217F;

pub fn zipf_lm_split(
    vocab: usize,
    seq_len: usize,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> Split {
    // degenerate-size guards: a 0-length sequence has no (x, y) pair to
    // emit and an empty vocabulary has no tokens to draw — floor both so
    // every call returns a well-formed split (n = 0 is fine: it is just
    // an empty dataset with valid shapes)
    let vocab = vocab.max(1);
    let seq_len = seq_len.max(1);
    // Zipf unigram weights
    let weights: Vec<f64> = (0..vocab).map(|i| 1.0 / (i as f64 + 1.0)).collect();
    // deterministic "preferred successor" permutation-ish map
    let succ: Vec<usize> = (0..vocab).map(|t| (t * 7 + 3) % vocab).collect();

    let make = |n: usize, name: &str, stream: u64| {
        let mut rng = StreamRng::new(seed ^ stream);
        let mut x = Vec::with_capacity(n * seq_len);
        let mut y = Vec::with_capacity(n * seq_len);
        for _ in 0..n {
            let mut tok = rng.weighted(&weights);
            let mut seq = Vec::with_capacity(seq_len + 1);
            seq.push(tok);
            for _ in 0..seq_len {
                tok = if rng.uniform() < 0.7 {
                    succ[tok]
                } else {
                    rng.weighted(&weights)
                };
                seq.push(tok);
            }
            for t in 0..seq_len {
                x.push(seq[t] as f32);
                y.push(seq[t + 1] as f32);
            }
        }
        Dataset {
            name: name.into(),
            n,
            x_shape: vec![seq_len],
            y_shape: vec![seq_len],
            x,
            y,
            classes: vocab,
        }
    };
    let train = make(n_train, "zipf_train", TRAIN_STREAM);
    let test = make(n_test, "zipf_test", TEST_STREAM);
    Split { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_shift_alignment() {
        let s = zipf_lm_split(64, 32, 16, 8, 1);
        assert_eq!(s.train.x.len(), 16 * 32);
        assert_eq!(s.train.y.len(), 16 * 32);
        // y[t] must equal x[t+1] within a sequence
        for i in 0..16 {
            let xs = s.train.sample_x(i);
            let ys = s.train.sample_y(i);
            for t in 0..31 {
                assert_eq!(ys[t], xs[t + 1]);
            }
        }
    }

    #[test]
    fn bigram_structure_dominates() {
        let s = zipf_lm_split(64, 64, 64, 8, 2);
        // count how often y == succ(x)
        let mut hits = 0usize;
        let mut total = 0usize;
        for i in 0..s.train.n {
            let xs = s.train.sample_x(i);
            let ys = s.train.sample_y(i);
            for t in 0..64 {
                let x = xs[t] as usize;
                if ys[t] as usize == (x * 7 + 3) % 64 {
                    hits += 1;
                }
                total += 1;
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.6, "bigram rate {frac}");
    }

    #[test]
    fn tokens_in_vocab() {
        let s = zipf_lm_split(16, 8, 32, 8, 3);
        assert!(s.train.x.iter().all(|&t| (0.0..16.0).contains(&t)));
    }
}
