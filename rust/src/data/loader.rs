//! Epoch-shuffled mini-batch loader over an in-memory [`Dataset`].

use crate::rng::StreamRng;

use super::Dataset;

pub struct Loader<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: StreamRng,
    pub epoch: usize,
    // reusable batch buffers (hot path: no per-step allocation)
    xbuf: Vec<f32>,
    ybuf: Vec<f32>,
}

impl<'a> Loader<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch <= ds.n, "batch {} > dataset {}", batch, ds.n);
        let mut rng = StreamRng::new(seed ^ 0x10AD);
        let mut order: Vec<usize> = (0..ds.n).collect();
        rng.shuffle(&mut order);
        Loader {
            ds,
            batch,
            order,
            pos: 0,
            rng,
            epoch: 0,
            xbuf: vec![0.0; batch * ds.x_elem()],
            ybuf: vec![0.0; batch * ds.y_elem()],
        }
    }

    /// Steps per epoch (drop-last discipline).
    pub fn steps_per_epoch(&self) -> usize {
        (self.ds.n / self.batch).max(1)
    }

    /// Advance the stream by one batch WITHOUT filling the buffers —
    /// checkpoint-resume replay. Leaves the shuffle state exactly as a
    /// next_batch() call would, at zero copy cost.
    pub fn skip_batch(&mut self) {
        if self.pos + self.batch > self.ds.n {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
        }
        self.pos += self.batch;
    }

    /// Fill the internal buffers with the next batch and return views.
    pub fn next_batch(&mut self) -> (&[f32], &[f32]) {
        if self.pos + self.batch > self.ds.n {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
            self.epoch += 1;
        }
        let xe = self.ds.x_elem();
        let ye = self.ds.y_elem();
        for b in 0..self.batch {
            let i = self.order[self.pos + b];
            self.xbuf[b * xe..(b + 1) * xe].copy_from_slice(self.ds.sample_x(i));
            self.ybuf[b * ye..(b + 1) * ye].copy_from_slice(self.ds.sample_y(i));
        }
        self.pos += self.batch;
        (&self.xbuf, &self.ybuf)
    }

    /// Sequential (unshuffled) batches for evaluation; returns None past
    /// the end. `cursor` advances by whole batches (drop-last).
    pub fn eval_batch(ds: &'a Dataset, batch: usize, cursor: &mut usize, xbuf: &mut Vec<f32>, ybuf: &mut Vec<f32>) -> bool {
        if *cursor + batch > ds.n {
            return false;
        }
        xbuf.clear();
        ybuf.clear();
        for i in *cursor..*cursor + batch {
            xbuf.extend_from_slice(ds.sample_x(i));
            ybuf.extend_from_slice(ds.sample_y(i));
        }
        *cursor += batch;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::flat_split;

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let s = flat_split(8, 4, 64, 16, 1);
        let mut loader = Loader::new(&s.train, 16, 0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            let (x, _) = loader.next_batch();
            // fingerprint each sample by its bits
            for b in 0..16 {
                let row = &x[b * 8..(b + 1) * 8];
                let fp: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
                seen.insert(fp);
            }
        }
        assert_eq!(seen.len(), 64, "epoch did not cover each sample once");
        assert_eq!(loader.epoch, 0);
        loader.next_batch();
        assert_eq!(loader.epoch, 1);
    }

    #[test]
    fn skip_batch_matches_next_batch_stream() {
        let s = flat_split(8, 4, 64, 16, 5);
        // skip across an epoch boundary (64/16 = 4 batches per epoch)
        let mut a = Loader::new(&s.train, 16, 9);
        let mut b = Loader::new(&s.train, 16, 9);
        for _ in 0..6 {
            a.next_batch();
            b.skip_batch();
        }
        assert_eq!(a.epoch, b.epoch);
        let (xa, ya) = a.next_batch();
        let (xa, ya) = (xa.to_vec(), ya.to_vec());
        let (xb, yb) = b.next_batch();
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn eval_batches_are_sequential() {
        let s = flat_split(4, 2, 40, 16, 2);
        let mut cursor = 0;
        let (mut xb, mut yb) = (Vec::new(), Vec::new());
        let mut n = 0;
        while Loader::eval_batch(&s.test, 8, &mut cursor, &mut xb, &mut yb) {
            assert_eq!(xb.len(), 8 * 4);
            n += 1;
        }
        assert_eq!(n, 2);
    }
}
