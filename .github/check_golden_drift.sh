#!/usr/bin/env bash
# Golden-vector drift guard.
#
# rust/tests/data/golden_quant.json pins the cross-layer quantizer
# semantics (rust vs the Python reference). Regenerating it is a
# deliberate, documented act: any diff that touches the golden file must
# also touch rust/README.md's "Golden vector regeneration" section in
# the same change, or CI fails here.
#
# Diff range: merge-base..HEAD on pull requests; the full pushed range
# (PUSH_BASE_SHA, set by CI from github.event.before) on push events so
# multi-commit pushes can't slip a golden change past the guard. First
# commits / new branches pass trivially.
set -euo pipefail

GOLDENS=(
  "rust/tests/data/golden_quant.json"
  "rust/tests/data/golden_report_fingerprints.json"
  "rust/tests/data/golden_ledger_v1.jsonl"
)
README="rust/README.md"

ZERO_SHA="0000000000000000000000000000000000000000"
if [[ -n "${GITHUB_BASE_REF:-}" ]]; then
  git fetch --quiet origin "$GITHUB_BASE_REF"
  range="origin/${GITHUB_BASE_REF}...HEAD"
elif [[ -n "${PUSH_BASE_SHA:-}" && "$PUSH_BASE_SHA" != "$ZERO_SHA" ]] \
  && git rev-parse --verify --quiet "$PUSH_BASE_SHA" >/dev/null; then
  range="${PUSH_BASE_SHA}..HEAD"
elif git rev-parse --verify --quiet HEAD~1 >/dev/null; then
  range="HEAD~1..HEAD"
else
  echo "golden-drift: initial commit, nothing to compare"
  exit 0
fi

changed="$(git diff --name-only "$range")"

touched=""
for golden in "${GOLDENS[@]}"; do
  if grep -qx "$golden" <<<"$changed"; then
    touched="$golden"
    break
  fi
done

if [[ -z "$touched" ]]; then
  echo "golden-drift: no golden file changed in $range — ok"
  exit 0
fi

if ! grep -qx "$README" <<<"$changed"; then
  echo "golden-drift: FAIL"
  echo "  $touched changed in $range but $README did not."
  echo "  Regenerating goldens must be documented: update the"
  echo "  'Golden vector regeneration' section of $README (why the"
  echo "  pinned semantics changed, and with which reference) in"
  echo "  the same change."
  exit 1
fi

if ! git diff "$range" -- "$README" | grep -qi "golden"; then
  echo "golden-drift: FAIL"
  echo "  $touched changed and $README was edited, but the edit does not"
  echo "  touch the golden regeneration documentation (no diff"
  echo "  line mentions 'golden'). Document the regeneration in the"
  echo "  'Golden vector regeneration' section."
  exit 1
fi

echo "golden-drift: $touched changed together with its $README docs — ok"
