#!/usr/bin/env python3
"""Render the gemm/*, attn/*, infer/* and net/* entries of a
swalp-bench-v1 JSON as markdown tables.

CI's bench-smoke job pipes the output into $GITHUB_STEP_SUMMARY so the
GEMM GFLOP/s trend — and the inference batching amplification — are
visible on the run page without downloading the BENCH_hotpath.json
artifact. Schema: docs/PERF.md.
"""
import json
import sys


def main(path: str) -> int:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "swalp-bench-v1":
        print(f"unexpected schema in {path}: {doc.get('schema')!r}", file=sys.stderr)
        return 1
    # timing entries carry median_s; throughput entries carry unit/value
    # under the same name — join the two streams by name
    medians = {}
    gflops = {}
    order = []
    for r in doc.get("results", []):
        name = r.get("name", "")
        if not name.startswith("gemm/"):
            continue
        if "median_s" in r:
            medians[name] = r["median_s"]
        if r.get("unit") == "GFLOP/s":
            if name not in gflops:
                order.append(name)
            gflops[name] = r["value"]
    print("### GEMM engine (swalp-bench-v1, quick mode)\n")
    if not order:
        print("_no gemm/* entries in this artifact_")
        return 0
    print("| bench | GFLOP/s | median ms/iter |")
    print("|---|---:|---:|")
    for name in order:
        med = medians.get(name)
        med_ms = f"{med * 1e3:.2f}" if med is not None else "—"
        print(f"| `{name}` | {gflops[name]:.2f} | {med_ms} |")
    naive = gflops.get("gemm/naive serial 256^3")
    blocked = gflops.get("gemm/blocked 256^3")
    if naive and blocked:
        print(f"\nblocked / naive-serial speedup on 256^3: **{blocked / naive:.1f}x**")
    # scalar-vs-SIMD delta (rows exist only when a vector kernel was
    # compiled in and detected — docs/PERF.md § "SIMD micro-kernels")
    simd = gflops.get("gemm/blocked-simd 256^3")
    if blocked and simd:
        print(f"\nblocked-simd / blocked (scalar) speedup on 256^3: **{simd / blocked:.1f}x**")
    fma = gflops.get("gemm/blocked-fma 256^3")
    if blocked and fma:
        print(f"\nblocked-fma / blocked (scalar) speedup on 256^3: **{fma / blocked:.1f}x**")
    fused = gflops.get("gemm/fused fixed-W8F6 256^3")
    fused_simd = gflops.get("gemm/fused-simd fixed-W8F6 256^3")
    if fused and fused_simd:
        print(f"\nfused-simd / fused (scalar) speedup on 256^3: **{fused_simd / fused:.1f}x**")
    attn_section(doc)
    infer_section(doc)
    net_section(doc)
    return 0


def attn_section(doc) -> None:
    """Attention-shape rows: per-head q·kᵀ scores and probs·v context
    GEMMs at LM sequence lengths (bench_perf_hotpath "attention-shape
    GEMMs" section)."""
    medians = {}
    gflops = {}
    order = []
    for r in doc.get("results", []):
        name = r.get("name", "")
        if not name.startswith("attn/"):
            continue
        if "median_s" in r:
            medians[name] = r["median_s"]
        if r.get("unit") == "GFLOP/s":
            if name not in order:
                order.append(name)
            gflops[name] = r["value"]
    if not order:
        return
    print("\n### Attention-shape GEMMs (transformer LM hot path)\n")
    print("| bench | GFLOP/s | median ms/iter |")
    print("|---|---:|---:|")
    for name in order:
        med = medians.get(name)
        med_ms = f"{med * 1e3:.2f}" if med is not None else "—"
        print(f"| `{name}` | {gflops[name]:.2f} | {med_ms} |")


def infer_section(doc) -> None:
    """Inference-serving rows: per-batch predict throughput plus the full
    batcher path, with the batch-64 / batch-1 amplification the serving
    design rides on (bench_perf_hotpath "inference serving" section)."""
    medians = {}
    sps = {}
    order = []
    for r in doc.get("results", []):
        name = r.get("name", "")
        if not name.startswith("infer/"):
            continue
        if "median_s" in r:
            medians[name] = r["median_s"]
        if r.get("unit") == "samples/s":
            if name not in order:
                order.append(name)
            sps[name] = r["value"]
    if not order:
        return
    print("\n### Inference serving (swalp-infer sessions)\n")
    print("| bench | samples/s | median ms/iter |")
    print("|---|---:|---:|")
    for name in order:
        med = medians.get(name)
        med_ms = f"{med * 1e3:.2f}" if med is not None else "—"
        print(f"| `{name}` | {sps[name]:.0f} | {med_ms} |")
    b1 = sps.get("infer/predict mlp_qmm_fx86 b=1")
    b64 = sps.get("infer/predict mlp_qmm_fx86 b=64")
    if b1 and b64:
        print(f"\nbatch-64 / batch-1 predict throughput on mlp_qmm_fx86: **{b64 / b1:.1f}x**")


def net_section(doc) -> None:
    """Network front-end rows: over-the-wire predict throughput and
    latency percentiles at 1/8/64 concurrent HTTP clients, with the
    overhead line against the in-process infer/batcher baseline
    (bench_perf_hotpath "network front-end" section)."""
    rps = {}
    p50 = {}
    p99 = {}
    order = []
    batcher_sps = None
    for r in doc.get("results", []):
        name = r.get("name", "")
        # the in-process baseline for the overhead line (the reqs/cli
        # counts in the name vary with --quick, so match the prefix)
        if name.startswith("infer/batcher") and r.get("unit") == "samples/s":
            batcher_sps = r["value"]
        if not name.startswith("net/"):
            continue
        if r.get("unit") == "req/s":
            if name not in order:
                order.append(name)
            rps[name] = r["value"]
        elif name.endswith(" p50") and r.get("unit") == "ms":
            p50[name[: -len(" p50")]] = r["value"]
        elif name.endswith(" p99") and r.get("unit") == "ms":
            p99[name[: -len(" p99")]] = r["value"]
    if not order:
        return
    print("\n### Network front-end (serve_net daemon over loopback)\n")
    print("| bench | req/s | p50 ms | p99 ms |")
    print("|---|---:|---:|---:|")
    for name in order:
        cells = [
            f"{v:.2f}" if v is not None else "—"
            for v in (p50.get(name), p99.get(name))
        ]
        print(f"| `{name}` | {rps[name]:.0f} | {cells[0]} | {cells[1]} |")
    wire = rps.get("net/predict mlp_qmm_fx86 c=8")
    if batcher_sps and wire:
        print(
            f"\nover-the-wire (c=8) vs in-process infer/batcher throughput: "
            f"**{wire / batcher_sps:.2f}x** "
            f"({wire:.0f} req/s over TCP vs {batcher_sps:.0f} samples/s in-process)"
        )


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_hotpath.json"))
